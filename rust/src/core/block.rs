//! Superblock execution engine — basic-block pre-decode and
//! block-amortized timing over the per-instruction oracle.
//!
//! ## Why blocks, not per-instruction caching
//!
//! The interpreter in [`Core::step`] pays, for every dynamic instruction:
//! a text-segment bounds check, an [`crate::isa::info`] table lookup, a
//! three-way operand-readiness `max` dispatched over register classes,
//! the full functional `exec` match, and the branch-prediction arm. An
//! earlier attempt to shave this cached pre-resolved metadata *per
//! instruction* and was measured ~8% **slower** than the plain
//! interpreter: the fatter per-step footprint (a wider struct fetched per
//! instruction) cost more in cache traffic than the `info()` lookup it
//! replaced, because the static `OP_TABLE` is already cache-resident in
//! hot loops. The lesson is that the win is not in caching metadata per
//! instruction but in **amortizing dispatch per block**: fetch, bounds
//! check, and block classification happen once per basic block, and the
//! dominant inner-loop idiom collapses to a single specialized loop with
//! no dispatch at all.
//!
//! ## The plan
//!
//! [`build_plan`] runs classic leader analysis over the pre-decoded text
//! segment at `load_program` time: instruction 0, every static
//! branch/jump target ([`Instr::branch_target`]), and every instruction
//! after a block terminator ([`crate::isa::OpInfo::ends_block`]) starts a
//! basic block. Each block carries its instructions with the static part
//! of the issue logic pre-resolved ([`PreInstr`]: functional unit, reg
//! classes, and the width-resolved `latency_for(fmt)`), plus a
//! classification:
//!
//! - [`BlockKind::Straight`] — straight-line code, optionally ending in a
//!   static-target branch or ECALL: executed by [`Core::run_block`] with
//!   one dispatch per block.
//! - [`BlockKind::FusedMac`] — the GEMM/dot inner-loop idiom of the
//!   paper's Fig. 5/6 kernels (posit load ×2 → `qmadd`/`qmsub` → pointer
//!   bumps → counter decrement → back-branch to the block's own head):
//!   whole loop *iterations* run inside [`Core::run_fused_mac`] without
//!   returning to the dispatcher. This is the n³ term of every Table 7
//!   row.
//! - [`BlockKind::Irregular`] — JALR anywhere in the block (dynamic
//!   target): falls back to the oracle [`Core::step`].
//!
//! ## Invariants
//!
//! 1. **Timing identity.** Every executor replicates the oracle's issue
//!    arithmetic in the oracle's order: operand-readiness stall first,
//!    then unit stall, then execute, then write-back/unit-free/cycle
//!    updates, then control flow, then `instret`/`max_instrs`. `Stats`
//!    and final architectural state are bit-and-count identical to
//!    running the same program through `step()` — pinned by the
//!    differential fuzz suite (`tests/engine_diff.rs`) and the bench
//!    pairs in `benches/table7_gemm_timing.rs`.
//! 2. **Leaders own entries.** A branch can only land on a block start
//!    (its target was made a leader), so block-at-a-time dispatch never
//!    enters a block mid-way; the only mid-block entries come from JALR,
//!    which the dispatcher routes through `step()` until the PC is back
//!    on a leader.
//! 3. **Live state.** The executors read and write `Core` architectural
//!    and scoreboard state directly (no values cached across
//!    instructions), so register aliasing inside a fused loop (`rb ==
//!    rs`, `pa == pb`, …) behaves exactly as it does in the oracle.
//!
//! ## The engine matrix
//!
//! Three engines produce this model's numbers, all bound by the same
//! identity contract — bit-and-count identical [`super::Stats`] and final
//! architectural state (registers, quire, memory) on every program:
//!
//! | engine                  | dispatch granularity | deopt points        | caching                  |
//! |-------------------------|----------------------|---------------------|--------------------------|
//! | [`Engine::Oracle`]      | one instruction      | — (it *is* the ref) | none                     |
//! | [`Engine::Superblock`]  | one basic block      | JALR, mid-block landings, unaligned PC | plan per `Arc<[Instr]>` |
//! | [`Engine::Translated`]  | host code per block ([`super::translate`]) | JALR, qsq/qlq, CSR reads, traps, quantum-adjacent blocks, mid-block landings, unaligned PC | plan + translation unit per `Arc<[Instr]>` |
//!
//! Every deopt routes through the verbatim [`Core::step`] oracle, so
//! traps, quantum expiry and the scheduler's checkpoint/migrate machinery
//! behave identically no matter which engine ran the surrounding code.
//! The contract is pinned by the three-way differential fuzzer
//! (`tests/engine_diff.rs`), the fault-injection suite, and hard asserts
//! in the bench pairs.

use super::Core;
use crate::isa::{info, Instr, Op, OpInfo, PositFmt, RegClass, Unit};
use crate::posit::unpacked::mask_n;

/// Which execution engine [`Core::run`] uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// Block-at-a-time superblock engine (the default).
    #[default]
    Superblock,
    /// The per-instruction interpreter, kept verbatim as the
    /// timing/semantics oracle.
    Oracle,
    /// Binary-translating engine: each basic block is lowered once into a
    /// threaded-code table of monomorphic host handlers, and the fused
    /// GEMM/dot inner loop into a single host-loop handler — see
    /// [`super::translate`]. Fastest on the host; identical numbers.
    Translated,
}

/// One instruction with the static part of its issue logic pre-resolved.
#[derive(Debug, Clone, Copy)]
pub(super) struct PreInstr {
    pub ins: Instr,
    pub unit: Unit,
    /// Width-resolved result latency (`OpInfo::latency_for(ins.fmt)`).
    pub lat: u64,
    pub rd: RegClass,
    pub rs1: RegClass,
    pub rs2: RegClass,
    pub rs3: RegClass,
}

impl PreInstr {
    fn new(ins: Instr) -> Self {
        let pi: &OpInfo = info(ins.op);
        Self {
            ins,
            unit: pi.unit,
            lat: pi.latency_for(ins.fmt),
            rd: pi.rd,
            rs1: pi.rs1,
            rs2: pi.rs2,
            rs3: pi.rs3,
        }
    }
}

/// The register/immediate skeleton of a fused MAC loop (see module doc):
///
/// ```text
/// head:  pl{b,h,w,d} pa, imm_a(ra)
///        pl{b,h,w,d} pb, imm_b(rb)
///        qmadd/qmsub.{b,h,s,d} pa, pb
///        addi ra, ra, step_a
///        add  rb, rb, rs_b        (or: addi rb, rb, step_b)
///        addi rc, rc, step_c
///        bnez rc, head
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) struct FusedMac {
    pub fmt: PositFmt,
    pub pa: u8,
    pub ra: u8,
    pub imm_a: i64,
    pub step_a: i64,
    pub pb: u8,
    pub rb: u8,
    pub imm_b: i64,
    /// `Some(rs)` for the `add rb, rb, rs` stride form, `None` for the
    /// `addi rb, rb, step_b` form (the dot kernel).
    pub rs_b: Option<u8>,
    pub step_b: i64,
    pub rc: u8,
    pub step_c: i64,
    /// QMSUB instead of QMADD.
    pub sub: bool,
    /// Static load latency (D$-hit cycles; the miss penalty is dynamic).
    pub load_lat: u64,
    /// Width-resolved QMADD/QMSUB latency.
    pub mac_lat: u64,
}

/// How a block executes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(super) enum BlockKind {
    Straight,
    FusedMac(FusedMac),
    Irregular,
}

/// One basic block: `start..start + pre.len()` instruction indices.
#[derive(Debug, Clone)]
pub(super) struct Block {
    pub start: usize,
    pub pre: Vec<PreInstr>,
    pub kind: BlockKind,
}

/// The whole program's superblock pre-decode.
#[derive(Debug, Clone, Default)]
pub(super) struct Plan {
    pub blocks: Vec<Block>,
    /// Instruction index → owning block id.
    pub block_of: Vec<u32>,
}

/// Partition a pre-decoded text segment into basic blocks (leader
/// analysis over static branch targets) and classify each one.
pub(super) fn build_plan(prog: &[Instr]) -> Plan {
    let n = prog.len();
    let mut leader = vec![false; n];
    if n > 0 {
        leader[0] = true;
    }
    for (i, ins) in prog.iter().enumerate() {
        if !info(ins.op).ends_block() {
            continue;
        }
        if i + 1 < n {
            leader[i + 1] = true;
        }
        if let Some(t) = ins.branch_target(i as u64 * 4) {
            let ti = (t / 4) as usize;
            if t % 4 == 0 && ti < n {
                leader[ti] = true;
            }
        }
    }
    let mut blocks = Vec::new();
    let mut block_of = vec![0u32; n];
    let mut s = 0;
    while s < n {
        let mut e = s + 1;
        while e < n && !leader[e] {
            e += 1;
        }
        let id = blocks.len() as u32;
        for slot in &mut block_of[s..e] {
            *slot = id;
        }
        let pre: Vec<PreInstr> = prog[s..e].iter().map(|ins| PreInstr::new(*ins)).collect();
        let kind = classify(&pre);
        blocks.push(Block { start: s, pre, kind });
        s = e;
    }
    Plan { blocks, block_of }
}

fn classify(pre: &[PreInstr]) -> BlockKind {
    // Dynamic-target control flow cannot be block-dispatched: the target
    // is invisible to the leader analysis, so the landing PC may be
    // mid-block. Route through the oracle step path instead.
    if pre.iter().any(|p| p.ins.op == Op::Jalr) {
        return BlockKind::Irregular;
    }
    match detect_fused_mac(pre) {
        Some(f) => BlockKind::FusedMac(f),
        None => BlockKind::Straight,
    }
}

/// Recognize the Fig. 5/6 inner-loop idiom (see [`FusedMac`]). The match
/// is purely structural — register aliasing is allowed because the fused
/// executor works on live core state — except that the written integer
/// registers must not be `x0` (whose writes the oracle discards).
fn detect_fused_mac(pre: &[PreInstr]) -> Option<FusedMac> {
    if pre.len() != 7 {
        return None;
    }
    let ins: Vec<Instr> = pre.iter().map(|p| p.ins).collect();
    let fmt = match ins[0].op {
        Op::Plb => PositFmt::P8,
        Op::Plh => PositFmt::P16,
        Op::Plw => PositFmt::P32,
        Op::Pld => PositFmt::P64,
        _ => return None,
    };
    if ins[1].op != ins[0].op {
        return None;
    }
    let (pa, ra, imm_a) = (ins[0].rd, ins[0].rs1, ins[0].imm);
    let (pb, rb, imm_b) = (ins[1].rd, ins[1].rs1, ins[1].imm);
    let sub = match ins[2].op {
        Op::QmaddS => false,
        Op::QmsubS => true,
        _ => return None,
    };
    if ins[2].fmt != fmt || ins[2].rs1 != pa || ins[2].rs2 != pb {
        return None;
    }
    if ins[3].op != Op::Addi || ins[3].rd != ra || ins[3].rs1 != ra {
        return None;
    }
    let step_a = ins[3].imm;
    let (rs_b, step_b) = match ins[4].op {
        Op::Add if ins[4].rd == rb && ins[4].rs1 == rb => (Some(ins[4].rs2), 0),
        Op::Addi if ins[4].rd == rb && ins[4].rs1 == rb => (None, ins[4].imm),
        _ => return None,
    };
    if ins[5].op != Op::Addi || ins[5].rd != ins[5].rs1 {
        return None;
    }
    let (rc, step_c) = (ins[5].rd, ins[5].imm);
    // `bnez rc` looping back to this block's own head (the only target a
    // 7-instruction block with this shape can have kept in one piece).
    if ins[6].op != Op::Bne || ins[6].rs1 != rc || ins[6].rs2 != 0 || ins[6].imm != -24 {
        return None;
    }
    if ra == 0 || rb == 0 || rc == 0 {
        return None;
    }
    Some(FusedMac {
        fmt,
        pa,
        ra,
        imm_a,
        step_a,
        pb,
        rb,
        imm_b,
        rs_b,
        step_b,
        rc,
        step_c,
        sub,
        load_lat: info(ins[0].op).latency as u64,
        mac_lat: info(ins[2].op).latency_for(fmt),
    })
}

impl Core {
    /// Issue an instruction: charge the RAW stall against `t_ops`, then
    /// the functional-unit stall, exactly as [`Core::step`] does, and
    /// return the issue cycle.
    #[inline]
    pub(super) fn issue(&mut self, t_ops: u64, unit: Unit) -> u64 {
        let mut t = self.cycle;
        if t_ops > t {
            self.raw_stalls += t_ops - t;
            t = t_ops;
        }
        let uf = self.unit_free[unit as usize];
        if uf > t {
            self.unit_stalls += uf - t;
            t = uf;
        }
        t
    }

    /// Retire bookkeeping shared by the block executors: mirrors the tail
    /// of [`Core::step`]. Returns `true` when the core halted.
    #[inline]
    pub(super) fn retire(&mut self) -> bool {
        self.instret += 1;
        if self.cfg.max_instrs != 0 && self.instret >= self.cfg.max_instrs {
            self.halted = true;
        }
        self.halted
    }

    /// Run the whole program block-at-a-time. The loop re-checks the plan
    /// on every transfer: branch targets are always leaders (invariant 2),
    /// and anything else — JALR landings, unaligned PCs — drops to the
    /// oracle `step()` until the PC is a leader again.
    pub(super) fn run_superblock(&mut self) {
        let plan = std::sync::Arc::clone(&self.plan);
        while !self.halted {
            let idx = (self.ctx.pc / 4) as usize;
            if self.ctx.pc % 4 != 0 || idx >= plan.block_of.len() {
                // Off the end of the text segment (or an unaligned JALR
                // landing): take the oracle path, which halts identically.
                if !self.step() {
                    break;
                }
                continue;
            }
            let block = &plan.blocks[plan.block_of[idx] as usize];
            if block.start != idx {
                // Mid-block entry (only reachable via JALR): step until
                // the PC lands on a leader.
                if !self.step() {
                    break;
                }
                continue;
            }
            match block.kind {
                BlockKind::Irregular => {
                    if !self.step() {
                        break;
                    }
                }
                BlockKind::FusedMac(f) => self.run_fused_mac(&f),
                BlockKind::Straight => self.run_block(&block.pre),
            }
        }
    }

    /// Execute one straight-line block: a single dispatch, then the
    /// pre-resolved issue skeleton per instruction. Timing logic is a
    /// line-for-line mirror of [`Core::step`] minus the fetch, the
    /// `info()` lookup and the latency resolution.
    fn run_block(&mut self, pre: &[PreInstr]) {
        for pi in pre {
            let ins = pi.ins;
            let t_ops = self
                .ready_of(pi.rs1, ins.rs1)
                .max(self.ready_of(pi.rs2, ins.rs2))
                .max(self.ready_of(pi.rs3, ins.rs3));
            let t = self.issue(t_ops, pi.unit);
            let eff = self.exec(&ins);
            // Trap latch — the oracle's arm, line for line: the faulting
            // instruction issued but does not retire.
            if let Some(trap) = eff.trap {
                self.cycle = t + 1;
                self.halted = true;
                self.halt_exit = false;
                self.trap = Some(trap);
                self.traps += 1;
                return;
            }
            let lat = pi.lat + eff.mem_extra;
            self.set_ready(pi.rd, ins.rd, t + lat);
            self.unit_free[pi.unit as usize] = match pi.unit {
                Unit::Pau | Unit::Fpu | Unit::Mul => t + lat,
                // Quire spills hold the D$ port for the whole multi-beat
                // walk (`lat` = pre-resolved latency_for + miss penalties),
                // mirroring the oracle's arm line for line.
                Unit::Lsu if matches!(ins.op, Op::Qlq | Op::Qsq) => t + lat,
                Unit::Lsu => t + 1 + eff.mem_extra,
                _ => t + 1,
            };
            self.cycle = t + 1;
            let next_seq = self.ctx.pc.wrapping_add(4);
            if pi.unit == Unit::Branch {
                let taken = eff.taken;
                let target = eff.next_pc.unwrap_or(next_seq);
                let predicted_target = match ins.op {
                    Op::Jal => target,
                    Op::Jalr => next_seq,
                    _ => {
                        if ins.imm < 0 {
                            self.ctx.pc.wrapping_add(ins.imm as u64)
                        } else {
                            next_seq
                        }
                    }
                };
                let actual = if taken { target } else { next_seq };
                if actual != predicted_target {
                    self.mispredicts += 1;
                    self.cycle += self.cfg.mispredict_penalty;
                }
                self.ctx.pc = actual;
            } else {
                self.ctx.pc = eff.next_pc.unwrap_or(next_seq);
            }
            if eff.halt {
                self.halted = true;
                self.halt_exit = true;
            }
            if self.retire() {
                return;
            }
        }
    }

    /// Execute fused MAC-loop iterations until the back-branch falls
    /// through (or `max_instrs` trips). Instruction-for-instruction the
    /// timing and state updates are the oracle's; what is gone is every
    /// per-instruction fetch, table lookup and match dispatch.
    pub(super) fn run_fused_mac(&mut self, f: &FusedMac) {
        let w = f.fmt.width();
        let mask = mask_n(w);
        let penalty = self.cfg.mispredict_penalty;
        loop {
            // ── load a: pl* pa, imm_a(ra) ─────────────────────────────
            let t = self.issue(self.ready_of(RegClass::X, f.ra), Unit::Lsu);
            let addr = self.ctx.x[f.ra as usize].wrapping_add(f.imm_a as u64);
            if let Some(trap) = self.mem_trap(addr, f.fmt.bytes()) {
                self.cycle = t + 1;
                self.halted = true;
                self.halt_exit = false;
                self.trap = Some(trap);
                self.traps += 1;
                return;
            }
            let me = self.dcache.access(addr);
            self.ctx.p[f.pa as usize] = self.read_posit_elem(addr, f.fmt);
            self.ready_p[f.pa as usize] = t + f.load_lat + me;
            self.unit_free[Unit::Lsu as usize] = t + 1 + me;
            self.cycle = t + 1;
            self.ctx.pc = self.ctx.pc.wrapping_add(4);
            if self.retire() {
                return;
            }

            // ── load b: pl* pb, imm_b(rb) ─────────────────────────────
            let t = self.issue(self.ready_of(RegClass::X, f.rb), Unit::Lsu);
            let addr = self.ctx.x[f.rb as usize].wrapping_add(f.imm_b as u64);
            if let Some(trap) = self.mem_trap(addr, f.fmt.bytes()) {
                self.cycle = t + 1;
                self.halted = true;
                self.halt_exit = false;
                self.trap = Some(trap);
                self.traps += 1;
                return;
            }
            let me = self.dcache.access(addr);
            self.ctx.p[f.pb as usize] = self.read_posit_elem(addr, f.fmt);
            self.ready_p[f.pb as usize] = t + f.load_lat + me;
            self.unit_free[Unit::Lsu as usize] = t + 1 + me;
            self.cycle = t + 1;
            self.ctx.pc = self.ctx.pc.wrapping_add(4);
            if self.retire() {
                return;
            }

            // ── qmadd/qmsub pa, pb ────────────────────────────────────
            let t_ops = self.ready_p[f.pa as usize].max(self.ready_p[f.pb as usize]);
            let t = self.issue(t_ops, Unit::Pau);
            let (a, b) = (self.ctx.p[f.pa as usize] & mask, self.ctx.p[f.pb as usize] & mask);
            if f.sub {
                self.ctx.quire.msub(f.fmt, a, b);
            } else {
                self.ctx.quire.madd(f.fmt, a, b);
            }
            self.unit_free[Unit::Pau as usize] = t + f.mac_lat;
            self.cycle = t + 1;
            self.ctx.pc = self.ctx.pc.wrapping_add(4);
            if self.retire() {
                return;
            }

            // ── addi ra, ra, step_a ───────────────────────────────────
            let t = self.issue(self.ready_of(RegClass::X, f.ra), Unit::Alu);
            self.ctx.x[f.ra as usize] = self.ctx.x[f.ra as usize].wrapping_add(f.step_a as u64);
            self.set_ready(RegClass::X, f.ra, t + 1);
            self.unit_free[Unit::Alu as usize] = t + 1;
            self.cycle = t + 1;
            self.ctx.pc = self.ctx.pc.wrapping_add(4);
            if self.retire() {
                return;
            }

            // ── add rb, rb, rs_b  /  addi rb, rb, step_b ──────────────
            let (t_ops, add) = match f.rs_b {
                Some(rs) => (
                    self.ready_of(RegClass::X, f.rb).max(self.ready_of(RegClass::X, rs)),
                    self.ctx.x[rs as usize],
                ),
                None => (self.ready_of(RegClass::X, f.rb), f.step_b as u64),
            };
            let t = self.issue(t_ops, Unit::Alu);
            self.ctx.x[f.rb as usize] = self.ctx.x[f.rb as usize].wrapping_add(add);
            self.set_ready(RegClass::X, f.rb, t + 1);
            self.unit_free[Unit::Alu as usize] = t + 1;
            self.cycle = t + 1;
            self.ctx.pc = self.ctx.pc.wrapping_add(4);
            if self.retire() {
                return;
            }

            // ── addi rc, rc, step_c ───────────────────────────────────
            let t = self.issue(self.ready_of(RegClass::X, f.rc), Unit::Alu);
            self.ctx.x[f.rc as usize] = self.ctx.x[f.rc as usize].wrapping_add(f.step_c as u64);
            self.set_ready(RegClass::X, f.rc, t + 1);
            self.unit_free[Unit::Alu as usize] = t + 1;
            self.cycle = t + 1;
            self.ctx.pc = self.ctx.pc.wrapping_add(4);
            if self.retire() {
                return;
            }

            // ── bnez rc, head (backward → predicted taken) ────────────
            let t = self.issue(self.ready_of(RegClass::X, f.rc), Unit::Branch);
            self.unit_free[Unit::Branch as usize] = t + 1;
            self.cycle = t + 1;
            let taken = self.ctx.x[f.rc as usize] != 0;
            if taken {
                self.ctx.pc = self.ctx.pc.wrapping_add(-24i64 as u64);
            } else {
                // Loop exit: the only mispredict of the whole loop.
                self.mispredicts += 1;
                self.cycle += penalty;
                self.ctx.pc = self.ctx.pc.wrapping_add(4);
            }
            if self.retire() || !taken {
                return;
            }
        }
    }

    /// Posit-element load at the format's memory width (the `pl*` data
    /// path of [`Core::exec`], inlined for the fused loop).
    #[inline]
    pub(super) fn read_posit_elem(&self, addr: u64, fmt: PositFmt) -> u64 {
        match fmt {
            PositFmt::P8 => self.mem.read_u8(addr) as u64,
            PositFmt::P16 => self.mem.read_u16(addr) as u64,
            PositFmt::P32 => self.mem.read_u32(addr) as u64,
            PositFmt::P64 => self.mem.read_u64(addr),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::assemble;

    fn plan_of(src: &str) -> Plan {
        build_plan(&assemble(src).expect("assembles").instrs)
    }

    #[test]
    fn leaders_split_at_branches_and_targets() {
        let p = plan_of(
            r#"
            li a0, 0
            li a1, 10
        loop:
            add a0, a0, a1
            addi a1, a1, -1
            bnez a1, loop
            ecall
        "#,
        );
        // Blocks: [li, li][add, addi, bnez][ecall].
        assert_eq!(p.blocks.len(), 3);
        assert_eq!(p.blocks[0].start, 0);
        assert_eq!(p.blocks[1].start, 2);
        assert_eq!(p.blocks[1].pre.len(), 3);
        assert_eq!(p.blocks[2].start, 5);
        assert_eq!(p.block_of, vec![0, 0, 1, 1, 1, 2]);
    }

    #[test]
    fn jalr_blocks_are_irregular() {
        let p = plan_of("jalr ra, 0(a0)\necall");
        assert_eq!(p.blocks[0].kind, BlockKind::Irregular);
        assert_eq!(p.blocks[1].kind, BlockKind::Straight);
    }

    #[test]
    fn quire_spills_terminate_blocks() {
        // qsq/qlq are block terminators (context-switch boundaries), so
        // straight-line code around them splits into separate blocks and
        // the instruction after a spill is a leader.
        let p = plan_of(
            r#"
            li a0, 0x400
            qsq.s (a0)
            addi a1, a1, 1
            qlq.d (a0)
            ecall
        "#,
        );
        // Blocks: [li, qsq][addi, qlq][ecall].
        assert_eq!(p.blocks.len(), 3);
        assert_eq!(p.blocks[0].pre.len(), 2);
        assert_eq!(p.blocks[1].start, 2);
        assert_eq!(p.blocks[2].start, 4);
        for b in &p.blocks {
            assert_eq!(b.kind, BlockKind::Straight);
        }
        // The pre-resolved latency carries the width-scaled beat count.
        assert_eq!(p.blocks[0].pre[1].lat, PositFmt::P32.quire_beats());
        assert_eq!(p.blocks[1].pre[1].lat, PositFmt::P64.quire_beats() + 2);
    }

    #[test]
    fn gemm_inner_loop_detected_every_width() {
        for (load, sfx, eb) in
            [("plb", "b", 1), ("plh", "h", 2), ("plw", "s", 4), ("pld", "d", 8)]
        {
            let src = format!(
                r#"
                li t5, 64
            loop_k:
                {load} p0, 0(t2)
                {load} p1, 0(t3)
                qmadd.{sfx} p0, p1
                addi t2, t2, {eb}
                add  t3, t3, t5
                addi s2, s2, -1
                bnez s2, loop_k
                ecall
            "#
            );
            let p = plan_of(&src);
            let loop_block =
                p.blocks.iter().find(|b| b.pre.len() == 7).expect("loop block");
            let BlockKind::FusedMac(f) = loop_block.kind else {
                panic!("{load}: inner loop not fused: {:?}", loop_block.kind);
            };
            assert_eq!(f.fmt.bytes() as i64, eb);
            assert_eq!(f.step_a, eb);
            assert_eq!(f.rs_b, Some(30)); // t5
            assert_eq!(f.step_c, -1);
            assert!(!f.sub);
        }
    }

    #[test]
    fn dot_inner_loop_detected_addi_form() {
        // The dot kernel bumps both pointers with addi (no stride reg).
        let p = plan_of(
            r#"
        loop:
            plw p0, 0(a0)
            plw p1, 0(a1)
            qmadd.s p0, p1
            addi a0, a0, 4
            addi a1, a1, 4
            addi a2, a2, -1
            bnez a2, loop
            ecall
        "#,
        );
        let BlockKind::FusedMac(f) = p.blocks[0].kind else {
            panic!("not fused: {:?}", p.blocks[0].kind);
        };
        assert_eq!(f.rs_b, None);
        assert_eq!(f.step_b, 4);
    }

    #[test]
    fn near_miss_idioms_stay_straight() {
        // Mismatched widths (plw feeding qmadd.h) must not fuse.
        let p = plan_of(
            r#"
        loop:
            plw p0, 0(a0)
            plw p1, 0(a1)
            qmadd.h p0, p1
            addi a0, a0, 4
            addi a1, a1, 4
            addi a2, a2, -1
            bnez a2, loop
            ecall
        "#,
        );
        assert_eq!(p.blocks[0].kind, BlockKind::Straight);
        // Counter written to x0 must not fuse (the write is discarded and
        // the loop never advances by that register).
        let p = plan_of(
            r#"
        loop:
            plw p0, 0(a0)
            plw p1, 0(a1)
            qmadd.s p0, p1
            addi a0, a0, 4
            addi a1, a1, 4
            addi zero, zero, -1
            bnez zero, loop
            ecall
        "#,
        );
        assert_eq!(p.blocks[0].kind, BlockKind::Straight);
    }
}
