//! Data memory and the L1 data-cache timing model.
//!
//! CVA6's L1 D$ on the Genesys II build is 32 KiB, 8-way set-associative
//! with 16-byte lines; misses go to DDR over AXI. The *functional* memory is
//! a flat little-endian byte array; the *timing* side is a tag-only cache
//! model (contents are irrelevant for timing, only hit/miss is) with LRU
//! replacement and write-allocate.
//!
//! The paper's GEMM timings (Table 7) are dominated by exactly this
//! structure: the B-matrix column walk strides `4n` bytes and starts
//! missing once `n` exceeds the cache's reach, which is why the 64→128
//! step in the paper grows ~28× rather than 8×.

/// Cache geometry + penalty configuration.
#[derive(Debug, Clone, Copy)]
pub struct CacheConfig {
    /// Total size in bytes (default 32 KiB, CVA6).
    pub size: usize,
    /// Associativity (default 8).
    pub ways: usize,
    /// Line size in bytes (default 16, CVA6's 128-bit lines).
    pub line: usize,
    /// Extra cycles on a miss (DRAM + AXI round trip at 50 MHz).
    pub miss_penalty: u64,
}

impl Default for CacheConfig {
    fn default() -> Self {
        // miss_penalty = 20 cycles is the one calibrated knob in the whole
        // timing model: chosen so the 64×64 f64 GEMM lands on the paper's
        // 69.4 ms (we get 69.8 ms); everything else then falls out — see
        // EXPERIMENTS.md §Calibration.
        Self { size: 32 * 1024, ways: 8, line: 16, miss_penalty: 20 }
    }
}

/// Tag-only LRU cache (timing model).
#[derive(Debug, Clone)]
pub struct DCache {
    cfg: CacheConfig,
    sets: usize,
    /// tags[set * ways + way] — tag value or u64::MAX for invalid.
    tags: Vec<u64>,
    /// Per-entry LRU stamp.
    stamp: Vec<u64>,
    /// Per-set most-recently-used way (fast-path probe — §Perf).
    mru: Vec<u8>,
    tick: u64,
    pub hits: u64,
    pub misses: u64,
}

impl DCache {
    pub fn new(cfg: CacheConfig) -> Self {
        let sets = cfg.size / (cfg.ways * cfg.line);
        assert!(sets.is_power_of_two(), "cache sets must be a power of two");
        Self {
            cfg,
            sets,
            tags: vec![u64::MAX; sets * cfg.ways],
            stamp: vec![0; sets * cfg.ways],
            mru: vec![0; sets],
            tick: 0,
            hits: 0,
            misses: 0,
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// Access `addr`; returns the extra latency (0 on hit, miss_penalty on
    /// miss) and updates the tag state.
    pub fn access(&mut self, addr: u64) -> u64 {
        self.tick += 1;
        let line = addr / self.cfg.line as u64;
        let set = (line as usize) & (self.sets - 1);
        let tag = line / self.sets as u64;
        let base = set * self.cfg.ways;
        // Fast path: the per-set MRU way (hot loops hammer one line per
        // set — §Perf optimisation, no LRU-order change).
        let m = base + self.mru[set] as usize;
        if self.tags[m] == tag {
            self.stamp[m] = self.tick;
            self.hits += 1;
            return 0;
        }
        // Hit?
        for w in 0..self.cfg.ways {
            if self.tags[base + w] == tag {
                self.stamp[base + w] = self.tick;
                self.mru[set] = w as u8;
                self.hits += 1;
                return 0;
            }
        }
        // Miss: fill LRU way.
        self.misses += 1;
        let mut victim = 0;
        let mut oldest = u64::MAX;
        for w in 0..self.cfg.ways {
            if self.stamp[base + w] < oldest {
                oldest = self.stamp[base + w];
                victim = w;
            }
        }
        self.tags[base + victim] = tag;
        self.stamp[base + victim] = self.tick;
        self.mru[set] = victim as u8;
        self.cfg.miss_penalty
    }

    /// [`Self::access`] with the MRU re-hit made free: when the per-set
    /// MRU way already holds the line, count the hit and return without
    /// bumping `tick` or the way's stamp. This is *exactly* equivalent to
    /// `access` for every observable (hit/miss counts and all future
    /// victim choices): the MRU way's stamp was the set's maximum when it
    /// became MRU, and any other way in the set can only gain a larger
    /// stamp through an access that also steals MRU status — so while a
    /// way stays MRU its stamp is already the within-set maximum and
    /// refreshing it changes no within-set order. Victim selection only
    /// compares stamps *within* a set, and written stamps stay unique and
    /// ordered the same with or without the skipped ticks (ties only
    /// occur between never-written zero stamps, in both variants). The
    /// translated engine's fused-MAC loop uses this; the oracle keeps
    /// plain `access` so the equivalence is load-bearing, not cosmetic.
    #[inline]
    pub fn access_mru(&mut self, addr: u64) -> u64 {
        let line = addr / self.cfg.line as u64;
        let set = (line as usize) & (self.sets - 1);
        let tag = line / self.sets as u64;
        let m = set * self.cfg.ways + self.mru[set] as usize;
        if self.tags[m] == tag {
            self.hits += 1;
            return 0;
        }
        self.access(addr)
    }

    /// Drop all lines (used between benchmark repetitions when modelling
    /// cold caches; the paper explicitly *avoids* cold misses, so the
    /// harness warms instead).
    pub fn flush(&mut self) {
        self.tags.fill(u64::MAX);
        self.stamp.fill(0);
        self.mru.fill(0);
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
    }
}

/// Flat little-endian data memory.
#[derive(Debug, Clone)]
pub struct Memory {
    bytes: Vec<u8>,
}

impl Memory {
    pub fn new(size: usize) -> Self {
        Self { bytes: vec![0; size] }
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }

    /// The whole backing store — the differential harness compares final
    /// memory images byte-for-byte across execution engines.
    pub fn bytes(&self) -> &[u8] {
        &self.bytes
    }

    /// Whether `[addr, addr + n)` lies inside the backing store. The core
    /// probes this *before* touching memory or the D$ so a wild access
    /// becomes a recoverable [`crate::core::Trap::OutOfBounds`] instead of
    /// the host-API panic in [`Self::check`].
    #[inline]
    pub fn in_bounds(&self, addr: u64, n: usize) -> bool {
        (addr as usize).checked_add(n).is_some_and(|end| end <= self.bytes.len())
    }

    #[inline]
    fn check(&self, addr: u64, n: usize) -> usize {
        let a = addr as usize;
        assert!(
            a.checked_add(n).is_some_and(|end| end <= self.bytes.len()),
            "memory access out of range: {addr:#x}+{n} (mem size {:#x})",
            self.bytes.len()
        );
        a
    }

    #[inline]
    pub fn read_u8(&self, addr: u64) -> u8 {
        self.bytes[self.check(addr, 1)]
    }

    #[inline]
    pub fn read_u16(&self, addr: u64) -> u16 {
        let a = self.check(addr, 2);
        u16::from_le_bytes(self.bytes[a..a + 2].try_into().unwrap())
    }

    #[inline]
    pub fn read_u32(&self, addr: u64) -> u32 {
        let a = self.check(addr, 4);
        u32::from_le_bytes(self.bytes[a..a + 4].try_into().unwrap())
    }

    #[inline]
    pub fn read_u64(&self, addr: u64) -> u64 {
        let a = self.check(addr, 8);
        u64::from_le_bytes(self.bytes[a..a + 8].try_into().unwrap())
    }

    #[inline]
    pub fn write_u8(&mut self, addr: u64, v: u8) {
        let a = self.check(addr, 1);
        self.bytes[a] = v;
    }

    #[inline]
    pub fn write_u16(&mut self, addr: u64, v: u16) {
        let a = self.check(addr, 2);
        self.bytes[a..a + 2].copy_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn write_u32(&mut self, addr: u64, v: u32) {
        let a = self.check(addr, 4);
        self.bytes[a..a + 4].copy_from_slice(&v.to_le_bytes());
    }

    #[inline]
    pub fn write_u64(&mut self, addr: u64, v: u64) {
        let a = self.check(addr, 8);
        self.bytes[a..a + 8].copy_from_slice(&v.to_le_bytes());
    }

    /// Raw byte-slice view — the quire spill/restore data path (`qsq`
    /// reads the image back with [`Self::read_bytes`]).
    #[inline]
    pub fn read_bytes(&self, addr: u64, n: usize) -> &[u8] {
        let a = self.check(addr, n);
        &self.bytes[a..a + n]
    }

    /// Raw byte-slice store (see [`Self::read_bytes`]).
    #[inline]
    pub fn write_bytes(&mut self, addr: u64, bytes: &[u8]) {
        let a = self.check(addr, bytes.len());
        self.bytes[a..a + bytes.len()].copy_from_slice(bytes);
    }

    /// Bulk helpers used by the workload generators.
    pub fn write_f32_slice(&mut self, addr: u64, xs: &[f32]) {
        for (i, x) in xs.iter().enumerate() {
            self.write_u32(addr + 4 * i as u64, x.to_bits());
        }
    }

    pub fn write_f64_slice(&mut self, addr: u64, xs: &[f64]) {
        for (i, x) in xs.iter().enumerate() {
            self.write_u64(addr + 8 * i as u64, x.to_bits());
        }
    }

    pub fn write_u32_slice(&mut self, addr: u64, xs: &[u32]) {
        for (i, x) in xs.iter().enumerate() {
            self.write_u32(addr + 4 * i as u64, *x);
        }
    }

    pub fn read_f32_slice(&self, addr: u64, n: usize) -> Vec<f32> {
        (0..n).map(|i| f32::from_bits(self.read_u32(addr + 4 * i as u64))).collect()
    }

    pub fn read_f64_slice(&self, addr: u64, n: usize) -> Vec<f64> {
        (0..n).map(|i| f64::from_bits(self.read_u64(addr + 8 * i as u64))).collect()
    }

    pub fn read_u32_slice(&self, addr: u64, n: usize) -> Vec<u32> {
        (0..n).map(|i| self.read_u32(addr + 4 * i as u64)).collect()
    }

    /// Write posit bit patterns (`u64`, lossless for every width) as
    /// packed `elem_bytes`-wide elements — the layout the multi-width
    /// posit loads/stores (`plb`/`plh`/`plw`/`pld`) address.
    pub fn write_posit_slice(&mut self, addr: u64, elem_bytes: usize, xs: &[u64]) {
        for (i, x) in xs.iter().enumerate() {
            let a = addr + (elem_bytes * i) as u64;
            match elem_bytes {
                1 => self.write_u8(a, *x as u8),
                2 => self.write_u16(a, *x as u16),
                4 => self.write_u32(a, *x as u32),
                8 => self.write_u64(a, *x),
                _ => panic!("unsupported posit element size {elem_bytes}"),
            }
        }
    }

    /// Read back packed posit bit patterns (see [`Self::write_posit_slice`]).
    pub fn read_posit_slice(&self, addr: u64, elem_bytes: usize, n: usize) -> Vec<u64> {
        (0..n)
            .map(|i| {
                let a = addr + (elem_bytes * i) as u64;
                match elem_bytes {
                    1 => self.read_u8(a) as u64,
                    2 => self.read_u16(a) as u64,
                    4 => self.read_u32(a) as u64,
                    8 => self.read_u64(a),
                    _ => panic!("unsupported posit element size {elem_bytes}"),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_rw_roundtrip() {
        let mut m = Memory::new(1024);
        m.write_u64(8, 0x1122_3344_5566_7788);
        assert_eq!(m.read_u64(8), 0x1122_3344_5566_7788);
        assert_eq!(m.read_u32(8), 0x5566_7788);
        assert_eq!(m.read_u32(12), 0x1122_3344);
        assert_eq!(m.read_u16(8), 0x7788);
        assert_eq!(m.read_u8(15), 0x11);
        m.write_u32(100, 0xDEAD_BEEF);
        assert_eq!(m.read_u32(100), 0xDEAD_BEEF);
    }

    #[test]
    fn posit_slice_roundtrip_every_width() {
        let mut m = Memory::new(1024);
        let xs = [0xA5u64, 0x7F, 0x01, 0xFE];
        for eb in [1usize, 2, 4, 8] {
            let masked: Vec<u64> =
                xs.iter().map(|x| x & (u64::MAX >> (64 - 8 * eb as u32))).collect();
            m.write_posit_slice(64, eb, &masked);
            assert_eq!(m.read_posit_slice(64, eb, xs.len()), masked, "eb={eb}");
        }
        // 64-bit patterns survive verbatim.
        let wide = [0x0123_4567_89AB_CDEFu64, u64::MAX];
        m.write_posit_slice(256, 8, &wide);
        assert_eq!(m.read_posit_slice(256, 8, 2), wide);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn oob_read_panics() {
        let m = Memory::new(16);
        m.read_u32(14);
    }

    #[test]
    fn cache_hits_after_first_touch() {
        let mut c = DCache::new(CacheConfig::default());
        assert_eq!(c.access(0x1000), c.config().miss_penalty);
        assert_eq!(c.access(0x1004), 0); // same 16B line
        assert_eq!(c.access(0x100C), 0);
        assert_eq!(c.access(0x1010), c.config().miss_penalty); // next line
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 2);
    }

    #[test]
    fn cache_lru_eviction() {
        // 2 sets × 2 ways × 16B = 64B cache: set = line index & 1.
        let mut c = DCache::new(CacheConfig { size: 64, ways: 2, line: 16, miss_penalty: 10 });
        // Three distinct lines mapping to set 0: 0x00, 0x40, 0x80.
        assert_eq!(c.access(0x00), 10);
        assert_eq!(c.access(0x40), 10);
        assert_eq!(c.access(0x00), 0); // both resident
        assert_eq!(c.access(0x80), 10); // evicts 0x40 (LRU)
        assert_eq!(c.access(0x00), 0);
        assert_eq!(c.access(0x40), 10); // was evicted
    }

    #[test]
    fn access_mru_is_equivalent_to_access() {
        // Drive two caches with the same pseudo-random conflict-heavy
        // stream, one routing everything through the MRU fast path:
        // hit/miss outcomes must agree access-for-access (the victim-order
        // argument documented on `access_mru`).
        let cfg = CacheConfig { size: 256, ways: 2, line: 16, miss_penalty: 10 };
        let mut a = DCache::new(cfg);
        let mut b = DCache::new(cfg);
        let mut x = 0x1234_5678u64;
        for _ in 0..10_000 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let addr = (x >> 33) & 0x3FF;
            assert_eq!(a.access(addr), b.access_mru(addr), "addr {addr:#x}");
        }
        assert_eq!((a.hits, a.misses), (b.hits, b.misses));
    }

    #[test]
    fn cache_capacity_reach() {
        // A 32 KiB cache must hold a 16 KiB array entirely.
        let mut c = DCache::new(CacheConfig::default());
        for pass in 0..2 {
            for addr in (0..16 * 1024u64).step_by(4) {
                let extra = c.access(addr);
                if pass == 1 {
                    assert_eq!(extra, 0, "second pass must fully hit");
                }
            }
        }
        // …and a 256 KiB stream must keep missing per line.
        c.reset_stats();
        for addr in (0x10_0000..0x14_0000u64).step_by(16) {
            c.access(addr);
        }
        assert_eq!(c.misses, (0x4_0000u64) / 16);
    }
}
