//! PERCIVAL core simulator — a CVA6-shaped, cycle-approximate, in-order
//! single-issue model with the paper's functional units and latencies.
//!
//! What is modelled (and why it is sufficient for Tables 7 & 8):
//! - **In-order single issue, out-of-order write-back via scoreboard**
//!   (paper §4.2): one instruction issues per cycle, stalling on RAW
//!   hazards against per-register ready times across all three register
//!   files (x/f/p).
//! - **Non-pipelined FPU and PAU** (paper §4.1: "there is no pipeline in
//!   the FPU nor the PAU... all operations are multi-cycle"): a new FPU/PAU
//!   op cannot issue until the previous one's result is done.
//! - **Unit latencies** from §4.1 via [`crate::isa::OpInfo::latency`].
//! - **L1 D$** (32 KiB / 8-way / 16 B lines, CVA6) with a flat miss
//!   penalty — the term that makes GEMM scale the way Table 7 shows.
//! - **Branch prediction**: backward-taken/forward-not-taken with a
//!   mispredict flush penalty (CVA6's front end resteer).
//! - **Multi-width Xposit** (PERI / Big-PERCIVAL direction): the posit
//!   register file is 64 bits wide, the PAU accumulator is a
//!   format-tagged [`PauQuire`], loads/stores exist at 1/2/4/8-byte D$
//!   widths, and PAU latencies scale with the format via
//!   [`crate::isa::OpInfo::latency_for`].
//! - **Hart contexts** (paper §8's save/restore direction): the
//!   architectural state lives in a save/restorable [`HartContext`] the
//!   core executes, and the quire — the one piece PERCIVAL could not
//!   context-switch — spills through the `qsq`/`qlq` instructions as a
//!   multi-beat D$ walk, so the multi-hart scheduler
//!   ([`crate::coordinator::sched`]) can time-slice many jobs over one
//!   simulated core with the switch cost cycle-accounted.
//!
//! What is not modelled: TLBs (benchmarks run bare), instruction cache
//! (kernels fit I$), store-buffer stalls, page walks. DESIGN.md discusses
//! why those do not move the Table 7/8 comparisons.
//!
//! Three execution engines produce this model's numbers ([`Engine`]):
//! the per-instruction interpreter [`Core::step`] (the timing/semantics
//! **oracle**, kept verbatim), the [`block`] superblock engine
//! (basic-block pre-decode + a fused fast path for the GEMM inner loop),
//! and the [`translate`] binary-translating engine (blocks lowered once
//! to threaded host-handler tables, the fused MAC loop to a single
//! hoisted host loop). All three are bit-and-count identical; each is
//! several times faster on the host than the previous. `Core::run`
//! dispatches on [`CoreConfig::engine`]; the full engine matrix is in
//! the [`block`] module doc.

pub mod block;
pub mod exec;
pub mod mem;
pub mod translate;

pub use block::Engine;
pub use mem::{CacheConfig, DCache, Memory};

use crate::isa::asm::Program;
use crate::isa::{info, Instr, Op, PositFmt, RegClass, Unit};
use crate::posit::unpacked::{Decoded, Unpacked};
use crate::posit::{PositFormat, Quire, Quire16, Quire32, Quire64, Quire8, SigWord};
use std::sync::Arc;

/// A recoverable fault latched by the core — the simulator's analogue of
/// paper Fig. 3's `illegal_instr` trap arm, generalized to the memory
/// system. A trap halts the core (`Core::halted()` turns true) without
/// retiring the faulting instruction; the scheduler inspects
/// [`Core::halt_cause`] and restarts or fails the job, so a misbehaving
/// program never panics the host. Both execution engines latch the
/// identical trap at the identical instruction count.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trap {
    /// Data access past the end of the configured memory.
    OutOfBounds { pc: u64, addr: u64, len: usize },
    /// Data access breaking the operand's natural alignment (CVA6 has no
    /// hardware misaligned support; the `qsq`/`qlq` quire walk requires
    /// 8-byte beat alignment).
    Misaligned { pc: u64, addr: u64, len: usize },
    /// PC not 4-byte aligned (a jump to a torn target).
    MisalignedPc { pc: u64 },
    /// Undecodable or unimplemented opcode ([`crate::isa::Op::Illegal`]).
    IllegalInstruction { pc: u64 },
    /// Synthetic fault injected by the scheduler's fault plan
    /// ([`crate::coordinator::FaultPlan`]).
    Injected { pc: u64 },
}

/// Why the core is halted — the three-way distinction the scheduler
/// dispatches on: job finished, quantum expired, or job faulted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HaltCause {
    /// Program exit: ECALL/EBREAK or running off the text segment.
    Exit,
    /// The `max_instrs` quantum valve fired.
    Quantum,
    /// A recoverable fault (see [`Trap`]).
    Trap(Trap),
}

/// FNV-1a over a byte stream — the checkpoint image checksum (no crates,
/// stable across hosts, good-enough corruption detection for a trailer).
fn fnv1a(bytes: &[u8]) -> u32 {
    let mut hash: u32 = 0x811c_9dc5;
    for &b in bytes {
        hash ^= b as u32;
        hash = hash.wrapping_mul(0x0100_0193);
    }
    hash
}

/// The PAU's accumulator, tagged with the posit width it currently holds —
/// one physical register reused across formats (Big-PERCIVAL's multi-width
/// PAU: a 16·N-bit quire per supported width, of which one is live).
/// Executing a quire instruction at a different width re-purposes the
/// register, clearing it first — as real multi-width hardware requires
/// software to `QCLR` when switching formats.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PauQuire {
    Q8(Quire8),
    Q16(Quire16),
    Q32(Quire32),
    Q64(Quire64),
}

impl PauQuire {
    pub fn new(fmt: PositFmt) -> Self {
        match fmt {
            PositFmt::P8 => PauQuire::Q8(Quire8::new()),
            PositFmt::P16 => PauQuire::Q16(Quire16::new()),
            PositFmt::P32 => PauQuire::Q32(Quire32::new()),
            PositFmt::P64 => PauQuire::Q64(Quire64::new()),
        }
    }

    /// Width of the accumulator's current format.
    pub fn fmt(&self) -> PositFmt {
        match self {
            PauQuire::Q8(_) => PositFmt::P8,
            PauQuire::Q16(_) => PositFmt::P16,
            PauQuire::Q32(_) => PositFmt::P32,
            PauQuire::Q64(_) => PositFmt::P64,
        }
    }

    /// Re-tag to `fmt`, clearing if the width changes.
    #[inline]
    fn retag(&mut self, fmt: PositFmt) {
        if self.fmt() != fmt {
            *self = Self::new(fmt);
        }
    }

    /// `QCLR` at `fmt` (re-tags the register to the new width).
    pub fn clear(&mut self, fmt: PositFmt) {
        self.retag(fmt);
        match self {
            PauQuire::Q8(q) => q.clear(),
            PauQuire::Q16(q) => q.clear(),
            PauQuire::Q32(q) => q.clear(),
            PauQuire::Q64(q) => q.clear(),
        }
    }

    /// `QNEG` at `fmt`.
    pub fn neg(&mut self, fmt: PositFmt) {
        self.retag(fmt);
        match self {
            PauQuire::Q8(q) => q.neg(),
            PauQuire::Q16(q) => q.neg(),
            PauQuire::Q32(q) => q.neg(),
            PauQuire::Q64(q) => q.neg(),
        }
    }

    /// `QMADD` at `fmt` (bit patterns travel as `u64`, lossless for every
    /// width).
    pub fn madd(&mut self, fmt: PositFmt, a: u64, b: u64) {
        self.retag(fmt);
        match self {
            PauQuire::Q8(q) => q.madd(a as u32, b as u32),
            PauQuire::Q16(q) => q.madd(a as u32, b as u32),
            PauQuire::Q32(q) => q.madd(a as u32, b as u32),
            PauQuire::Q64(q) => q.madd(a, b),
        }
    }

    /// `QMSUB` at `fmt`.
    pub fn msub(&mut self, fmt: PositFmt, a: u64, b: u64) {
        self.retag(fmt);
        match self {
            PauQuire::Q8(q) => q.msub(a as u32, b as u32),
            PauQuire::Q16(q) => q.msub(a as u32, b as u32),
            PauQuire::Q32(q) => q.msub(a as u32, b as u32),
            PauQuire::Q64(q) => q.msub(a, b),
        }
    }

    /// `QMADD`/`QMSUB` on pre-decoded operands — the translated engine's
    /// entry point ([`translate`]): operands arrive as the runtime-width
    /// engine's wide `Decoded<u64>` (from the memoized `decode_n`) and
    /// narrow here to the format's significand word, which is exact by
    /// construction ([`SigWord::from_wide`]: the discarded low bits are
    /// zero for every width). Bit-identical to [`Self::madd`]/
    /// [`Self::msub`], whose `F::decode` is the same `decode_n` + narrow
    /// composition.
    fn mac_decoded(&mut self, fmt: PositFmt, a: Decoded<u64>, b: Decoded<u64>, sub: bool) {
        fn narrow<S: SigWord>(d: Decoded<u64>) -> Decoded<S> {
            match d {
                Decoded::Zero => Decoded::Zero,
                Decoded::NaR => Decoded::NaR,
                Decoded::Num(u) => Decoded::Num(Unpacked {
                    sign: u.sign,
                    scale: u.scale,
                    sig: S::from_wide(u.sig),
                }),
            }
        }
        fn go<F: PositFormat>(q: &mut Quire<F>, a: Decoded<u64>, b: Decoded<u64>, sub: bool) {
            if sub {
                q.msub_unpacked(narrow(a), narrow(b));
            } else {
                q.madd_unpacked(narrow(a), narrow(b));
            }
        }
        self.retag(fmt);
        match self {
            PauQuire::Q8(q) => go(q, a, b, sub),
            PauQuire::Q16(q) => go(q, a, b, sub),
            PauQuire::Q32(q) => go(q, a, b, sub),
            PauQuire::Q64(q) => go(q, a, b, sub),
        }
    }

    /// `QROUND` at `fmt`.
    pub fn round(&mut self, fmt: PositFmt) -> u64 {
        self.retag(fmt);
        match self {
            PauQuire::Q8(q) => q.round() as u64,
            PauQuire::Q16(q) => q.round() as u64,
            PauQuire::Q32(q) => q.round() as u64,
            PauQuire::Q64(q) => q.round(),
        }
    }

    /// `QSQ` at `fmt` — serialize the accumulator to its 16·n-bit
    /// little-endian memory image ([`crate::posit::Quire::to_bytes`]).
    /// Like every quire instruction this re-tags the register first, so
    /// spilling at a width other than the live one spills the cleared
    /// re-tagged accumulator — software must spill at the format it
    /// accumulated at, exactly as multi-width hardware requires.
    pub fn spill(&mut self, fmt: PositFmt) -> Vec<u8> {
        let mut out = vec![0u8; fmt.quire_bytes()];
        self.spill_into(fmt, &mut out);
        out
    }

    /// [`Self::spill`] into a caller-provided buffer (exactly
    /// [`PositFmt::quire_bytes`] long) — the exec path's no-alloc `qsq`:
    /// a spill happens on every context switch and checkpoint, so the
    /// hot path writes straight into a stack buffer instead of
    /// allocating a `Vec` per instruction.
    pub fn spill_into(&mut self, fmt: PositFmt, out: &mut [u8]) {
        self.retag(fmt);
        match self {
            PauQuire::Q8(q) => q.write_bytes(out),
            PauQuire::Q16(q) => q.write_bytes(out),
            PauQuire::Q32(q) => q.write_bytes(out),
            PauQuire::Q64(q) => q.write_bytes(out),
        }
    }

    /// `QLQ` at `fmt` — restore an accumulator from a spill image,
    /// re-tagging the register to the instruction's width. The image
    /// length is fixed by `fmt` ([`PositFmt::quire_bytes`]); the caller
    /// (the core's exec path) always reads exactly that many bytes, so a
    /// length mismatch is a programming error, not a runtime one.
    pub fn restore(fmt: PositFmt, bytes: &[u8]) -> Self {
        Self::try_restore(fmt, bytes).expect("quire image length fixed by fmt")
    }

    /// Fallible [`Self::restore`] — the checkpoint-deserialisation path,
    /// where the image comes from an untrusted byte stream rather than
    /// the exec path's exact-length D$ read.
    pub fn try_restore(fmt: PositFmt, bytes: &[u8]) -> crate::error::Result<Self> {
        Ok(match fmt {
            PositFmt::P8 => PauQuire::Q8(Quire8::read_bytes(bytes)?),
            PositFmt::P16 => PauQuire::Q16(Quire16::read_bytes(bytes)?),
            PositFmt::P32 => PauQuire::Q32(Quire32::read_bytes(bytes)?),
            PositFmt::P64 => PauQuire::Q64(Quire64::read_bytes(bytes)?),
        })
    }

    /// The accumulator's 16·n-bit little-endian memory image at its
    /// *current* format, without re-tagging — the checkpoint
    /// serialisation path ([`HartContext::to_image`]), which must capture
    /// the live state verbatim rather than model a width-switching
    /// instruction like [`Self::spill`] does.
    pub fn image(&self) -> Vec<u8> {
        let mut out = vec![0u8; self.fmt().quire_bytes()];
        self.image_into(&mut out);
        out
    }

    /// [`Self::image`] into a caller-provided buffer (exactly the
    /// current format's [`PositFmt::quire_bytes`] long).
    pub fn image_into(&self, out: &mut [u8]) {
        match self {
            PauQuire::Q8(q) => q.write_bytes(out),
            PauQuire::Q16(q) => q.write_bytes(out),
            PauQuire::Q32(q) => q.write_bytes(out),
            PauQuire::Q64(q) => q.write_bytes(out),
        }
    }
}

/// The complete per-hart architectural state — everything a context
/// switch must save and restore: the three register files, the PC, and
/// the PAU's format-tagged quire accumulator (the piece the paper's §8
/// names as PERCIVAL's missing OS-support feature, and the one `qsq`/
/// `qlq` spill through the D$). [`Core`] *executes* a context rather
/// than owning its own: swapping `Core::ctx` is how the multi-hart
/// scheduler time-slices many jobs over one simulated core. The cycle
/// and instret counters stay on the core — they are per-hart hardware
/// counters (the `rdcycle`/`rdinstret` CSRs), not per-process state.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HartContext {
    /// Program counter.
    pub pc: u64,
    /// Integer register file `x0–x31` (`x0` reads as zero; the core
    /// discards writes to it).
    pub x: [u64; 32],
    /// Float register file `f0–f31` (F and D values, NaN-boxed).
    pub f: [u64; 32],
    /// Posit register file `p0–p31`. 64 bits wide since the multi-width
    /// extension (the Big-PERCIVAL configuration); narrower formats use
    /// the low bits, like the F registers hold both F and D values.
    pub p: [u64; 32],
    /// The PAU accumulator, tagged with its current posit width.
    pub quire: PauQuire,
}

impl HartContext {
    /// A fresh context: PC 0, zeroed register files, cleared P32 quire.
    pub fn new() -> Self {
        Self {
            pc: 0,
            x: [0; 32],
            f: [0; 32],
            p: [0; 32],
            quire: PauQuire::new(PositFmt::P32),
        }
    }

    /// Checkpoint image magic (`PCKP`).
    pub const IMAGE_MAGIC: [u8; 4] = *b"PCKP";
    /// Checkpoint image format version.
    pub const IMAGE_VERSION: u16 = 1;
    /// Header bytes before the register files: magic (4) + version (2) +
    /// quire format code (1) + flags (1) + pc (8).
    const IMAGE_HEADER: usize = 16;
    /// The three 32×u64 register files.
    const IMAGE_REGS: usize = 3 * 32 * 8;

    /// Serialize the full architectural state to a self-describing byte
    /// image — the unit of checkpoint/migrate in the multi-hart
    /// scheduler. Layout (all little-endian):
    ///
    /// | bytes            | field                                   |
    /// |------------------|-----------------------------------------|
    /// | 0..4             | magic `PCKP`                            |
    /// | 4..6             | version (u16, currently 1)              |
    /// | 6                | quire format code ([`PositFmt::bits`])  |
    /// | 7                | flags (reserved, 0)                     |
    /// | 8..16            | pc (u64)                                |
    /// | 16..272          | x0–x31 (u64 each)                       |
    /// | 272..528         | f0–f31                                  |
    /// | 528..784         | p0–p31                                  |
    /// | 784..784+16·n/8  | quire image ([`PauQuire::image`])       |
    /// | last 4           | FNV-1a checksum of everything before    |
    pub fn to_image(&self) -> Vec<u8> {
        let qlen = self.quire.fmt().quire_bytes();
        let mut out = Vec::with_capacity(Self::IMAGE_HEADER + Self::IMAGE_REGS + qlen + 4);
        out.extend_from_slice(&Self::IMAGE_MAGIC);
        out.extend_from_slice(&Self::IMAGE_VERSION.to_le_bytes());
        out.push(self.quire.fmt().bits() as u8);
        out.push(0);
        out.extend_from_slice(&self.pc.to_le_bytes());
        for file in [&self.x, &self.f, &self.p] {
            for v in file {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        let qstart = out.len();
        out.resize(qstart + qlen, 0);
        self.quire.image_into(&mut out[qstart..]);
        let sum = fnv1a(&out);
        out.extend_from_slice(&sum.to_le_bytes());
        out
    }

    /// Deserialize a checkpoint image, validating magic, version, format
    /// code, exact length, and checksum — a truncated, corrupted, or
    /// future-version image is a typed error, never a panic (the
    /// scheduler falls back to restarting the job from scratch).
    pub fn from_image(bytes: &[u8]) -> crate::error::Result<Self> {
        crate::ensure!(
            bytes.len() >= Self::IMAGE_HEADER,
            "checkpoint image truncated: {} bytes",
            bytes.len()
        );
        crate::ensure!(bytes[0..4] == Self::IMAGE_MAGIC, "bad checkpoint magic");
        let version = u16::from_le_bytes(bytes[4..6].try_into().unwrap());
        crate::ensure!(
            version == Self::IMAGE_VERSION,
            "unsupported checkpoint version {version}"
        );
        crate::ensure!(bytes[6] < 4, "bad checkpoint quire format code {}", bytes[6]);
        let fmt = PositFmt::from_bits(bytes[6] as u32);
        let expect = Self::IMAGE_HEADER + Self::IMAGE_REGS + fmt.quire_bytes() + 4;
        crate::ensure!(
            bytes.len() == expect,
            "checkpoint image is {} bytes, want {expect} for {}",
            bytes.len(),
            fmt.name()
        );
        let (body, sum) = bytes.split_at(bytes.len() - 4);
        let want = u32::from_le_bytes(sum.try_into().unwrap());
        crate::ensure!(fnv1a(body) == want, "checkpoint image checksum mismatch");

        let word = |i: usize| {
            u64::from_le_bytes(body[i..i + 8].try_into().unwrap())
        };
        let pc = word(8);
        let mut x = [0u64; 32];
        let mut f = [0u64; 32];
        let mut p = [0u64; 32];
        for i in 0..32 {
            x[i] = word(Self::IMAGE_HEADER + 8 * i);
            f[i] = word(Self::IMAGE_HEADER + 256 + 8 * i);
            p[i] = word(Self::IMAGE_HEADER + 512 + 8 * i);
        }
        let quire = PauQuire::try_restore(fmt, &body[Self::IMAGE_HEADER + Self::IMAGE_REGS..])?;
        Ok(Self { pc, x, f, p, quire })
    }
}

impl Default for HartContext {
    fn default() -> Self {
        Self::new()
    }
}

/// Timing configuration (defaults = Genesys II CVA6 at 50 MHz).
#[derive(Debug, Clone, Copy)]
pub struct CoreConfig {
    pub cache: CacheConfig,
    /// Flush penalty on a mispredicted branch/JALR (front-end resteer).
    pub mispredict_penalty: u64,
    /// Core clock in Hz (Genesys II timing closure at 20 ns → 50 MHz).
    pub freq_hz: u64,
    /// Data memory size in bytes.
    pub mem_size: usize,
    /// Safety valve for runaway programs (0 = unlimited).
    pub max_instrs: u64,
    /// Which execution engine [`Core::run`] uses. All engines produce
    /// bit-and-count identical `Stats` and architectural state; the
    /// superblock and translated engines are simply faster on the host.
    pub engine: Engine,
}

impl Default for CoreConfig {
    fn default() -> Self {
        Self {
            cache: CacheConfig::default(),
            mispredict_penalty: 5,
            freq_hz: 50_000_000,
            mem_size: 64 << 20,
            max_instrs: 0,
            engine: Engine::Superblock,
        }
    }
}

/// Execution statistics.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct Stats {
    pub cycles: u64,
    pub instret: u64,
    pub raw_stall_cycles: u64,
    pub unit_stall_cycles: u64,
    pub mispredicts: u64,
    pub dcache_hits: u64,
    pub dcache_misses: u64,
    /// Context switches dispatched on this hart (filled in by the
    /// multi-hart scheduler; a bare [`Core`] run reports 0).
    pub ctx_switches: u64,
    /// Cycles the hart spent in `qsq`/`qlq` context-switch save/restore
    /// sequences (scheduler-filled, like [`Self::ctx_switches`]).
    pub spill_cycles: u64,
    /// Recoverable faults latched by the core (see [`Trap`]).
    pub traps: u64,
    /// Checkpoint images captured (scheduler-filled).
    pub checkpoints: u64,
    /// Jobs migrated off a failed hart (scheduler-filled).
    pub migrations: u64,
    /// Job restarts after a trap, kill, or bad checkpoint
    /// (scheduler-filled).
    pub retries: u64,
    /// Jobs that blew their deadline (scheduler-filled).
    pub deadline_misses: u64,
}

impl Stats {
    /// Wall-clock seconds at the configured frequency.
    pub fn seconds(&self, cfg: &CoreConfig) -> f64 {
        self.cycles as f64 / cfg.freq_hz as f64
    }

    pub fn ipc(&self) -> f64 {
        self.instret as f64 / self.cycles.max(1) as f64
    }
}

/// The simulated core — an execution engine over *a* [`HartContext`]
/// rather than the owner of *the* architectural state: `ctx` is the
/// save/restorable per-hart state, everything else is the machine
/// (memory, D$, scoreboard, counters).
///
/// `Core` and [`HartContext`] are `Send` (pinned below): the service's
/// host-parallel hart pool runs one `Core` per `std::thread::scope`
/// worker and migrates jobs between workers by passing staged state —
/// including serialized [`HartContext::to_image`] checkpoints — over
/// channels.
pub struct Core {
    pub cfg: CoreConfig,
    /// The architectural context the core is currently executing.
    pub ctx: HartContext,
    pub mem: Memory,
    pub dcache: DCache,
    /// Pre-decoded text segment (PC 0 = index 0), shared with the
    /// [`Program`] it was loaded from — loading is an `Arc` bump.
    program: Arc<[Instr]>,
    /// Superblock pre-decode of `program` (see [`block`]), shared so the
    /// dispatch loop can hold it while executing against `&mut self`.
    plan: Arc<block::Plan>,
    /// Recently loaded plans keyed by text-segment identity (holding the
    /// `Arc` keeps each pointer stable, so `ptr_eq` is a sound key). The
    /// multi-hart scheduler alternates job kernels with the tiny
    /// `qsq`/`qlq` switch kernels on every context switch; without this
    /// cache each swap back would rebuild the job kernel's plan.
    plan_cache: Vec<(Arc<[Instr]>, Arc<block::Plan>)>,
    /// Translated-engine lowering of `program` and its LRU cache, keyed
    /// like `plan_cache` (`Arc::ptr_eq` on the text segment) — see
    /// [`translate`]. Built lazily on the first `Engine::Translated` run.
    trans_cache: Vec<(Arc<[Instr]>, Arc<translate::TransUnit>)>,
    /// Host-side posit-decode memo for the translated MAC loop (pure
    /// memoization, no simulated state; lazily allocated, survives
    /// `reset_timing`).
    dec_cache: Vec<translate::DecSlot>,
    /// Timing state.
    pub cycle: u64,
    pub instret: u64,
    ready_x: [u64; 32],
    ready_f: [u64; 32],
    ready_p: [u64; 32],
    /// Per-unit earliest next issue (non-pipelined units).
    unit_free: [u64; 7],
    raw_stalls: u64,
    unit_stalls: u64,
    mispredicts: u64,
    halted: bool,
    /// Whether the halt came from the program itself (ECALL/EBREAK or
    /// running off the text segment) rather than the `max_instrs` valve —
    /// the distinction the multi-hart scheduler needs between "job
    /// finished" and "quantum expired".
    halt_exit: bool,
    /// The fault behind the current halt, if any (takes precedence over
    /// both exit and quantum in [`Core::halt_cause`]).
    trap: Option<Trap>,
    /// Lifetime trap count (survives `clear_halt`; reset by
    /// [`Core::reset_timing`] like the stall counters).
    traps: u64,
}

// The host-parallel hart pool moves cores' state between OS threads;
// keep that property pinned at compile time (a non-Send field sneaking
// in — an `Rc`, a raw pointer — would break the service, not just fail
// a test).
const _: () = {
    const fn assert_send<T: Send>() {}
    assert_send::<Core>();
    assert_send::<HartContext>();
};

impl Core {
    pub fn new(cfg: CoreConfig) -> Self {
        Self {
            cfg,
            ctx: HartContext::new(),
            mem: Memory::new(cfg.mem_size),
            dcache: DCache::new(cfg.cache),
            program: Vec::new().into(),
            plan: Arc::new(block::Plan::default()),
            plan_cache: Vec::new(),
            trans_cache: Vec::new(),
            dec_cache: Vec::new(),
            cycle: 0,
            instret: 0,
            ready_x: [0; 32],
            ready_f: [0; 32],
            ready_p: [0; 32],
            unit_free: [0; 7],
            raw_stalls: 0,
            unit_stalls: 0,
            mispredicts: 0,
            halted: false,
            halt_exit: false,
            trap: None,
            traps: 0,
        }
    }

    /// Load a program's text segment at PC 0 and reset the PC. Shared
    /// storage: no copy of the text segment, only an `Arc` bump plus the
    /// (one-off, O(instructions)) superblock pre-decode.
    pub fn load_program(&mut self, prog: &Program) {
        self.load_instrs(Arc::clone(&prog.instrs));
    }

    /// Load a pre-decoded text segment directly (the differential
    /// harness builds instruction streams without assembling text).
    /// Re-loading the same shared segment (pointer-equal `Arc`) keeps
    /// the existing superblock plan — it is a pure function of the
    /// instructions.
    pub fn load_instrs(&mut self, instrs: Arc<[Instr]>) {
        if !Arc::ptr_eq(&self.program, &instrs) {
            if let Some(pos) =
                self.plan_cache.iter().position(|(seg, _)| Arc::ptr_eq(seg, &instrs))
            {
                // LRU: move the hit to the back so cyclic reloads (the
                // scheduler's round-robin over its job kernels) keep
                // hitting even at the capacity edge.
                let entry = self.plan_cache.remove(pos);
                self.plan = Arc::clone(&entry.1);
                self.plan_cache.push(entry);
            } else {
                self.plan = Arc::new(block::build_plan(&instrs));
                // Small bound: a hart cycles between a handful of job
                // kernels plus the eight 2-instruction switch kernels.
                if self.plan_cache.len() >= 16 {
                    self.plan_cache.remove(0);
                }
                self.plan_cache.push((Arc::clone(&instrs), Arc::clone(&self.plan)));
            }
            self.program = instrs;
        }
        self.ctx.pc = 0;
        self.halted = false;
        self.halt_exit = false;
        self.trap = None;
    }

    /// Clone out the architectural context — the save half of a context
    /// switch (the quire travels as part of the context; the scheduler
    /// additionally spills it through the `qsq` instruction so the save
    /// path is cycle-accounted and D$-visible).
    pub fn save_context(&self) -> HartContext {
        self.ctx.clone()
    }

    /// Install an architectural context and clear the halt latch — the
    /// restore half of a context switch. Timing state (cycle counter,
    /// scoreboard, D$) deliberately stays: the hart's timeline continues
    /// across the switch, which is the whole point of time-slicing on one
    /// simulated core.
    pub fn restore_context(&mut self, ctx: HartContext) {
        self.ctx = ctx;
        self.halted = false;
        self.halt_exit = false;
        self.trap = None;
    }

    /// Clear the halt latch without touching any other state — how the
    /// scheduler resumes the *same* job after a `max_instrs` quantum
    /// expiry (program-exit halts should not be resumed; check
    /// [`Core::halted_on_exit`] first).
    pub fn clear_halt(&mut self) {
        self.halted = false;
        self.halt_exit = false;
        self.trap = None;
    }

    /// Reset timing state (cycle counters, scoreboard, stats) but keep
    /// architectural state and cache contents — this is how the harness
    /// implements the paper's "avoiding cold misses" warm-up protocol.
    pub fn reset_timing(&mut self) {
        self.cycle = 0;
        self.instret = 0;
        self.ready_x = [0; 32];
        self.ready_f = [0; 32];
        self.ready_p = [0; 32];
        self.unit_free = [0; 7];
        self.raw_stalls = 0;
        self.unit_stalls = 0;
        self.mispredicts = 0;
        self.dcache.reset_stats();
        self.ctx.pc = 0;
        self.halted = false;
        self.halt_exit = false;
        self.trap = None;
        self.traps = 0;
    }

    pub fn halted(&self) -> bool {
        self.halted
    }

    /// True when the last halt was a program exit (ECALL/EBREAK or PC off
    /// the text segment) rather than a `max_instrs` quantum expiry.
    pub fn halted_on_exit(&self) -> bool {
        self.halt_exit
    }

    /// The fault behind the current halt, if any.
    pub fn trap(&self) -> Option<Trap> {
        self.trap
    }

    /// Why the core is halted (`None` while running). A latched trap
    /// takes precedence: a faulting instruction never also counts as a
    /// clean exit or a quantum expiry.
    pub fn halt_cause(&self) -> Option<HaltCause> {
        if !self.halted {
            return None;
        }
        Some(match self.trap {
            Some(t) => HaltCause::Trap(t),
            None if self.halt_exit => HaltCause::Exit,
            None => HaltCause::Quantum,
        })
    }

    /// Probe a data access for a fault *before* it touches memory or the
    /// D$ (so trap or not, both engines see identical cache state).
    /// Multi-byte scalars require natural alignment — CVA6 has no
    /// hardware misaligned-access support.
    #[inline]
    fn mem_trap(&self, addr: u64, len: usize) -> Option<Trap> {
        if len > 1 && addr % len as u64 != 0 {
            return Some(Trap::Misaligned { pc: self.ctx.pc, addr, len });
        }
        if !self.mem.in_bounds(addr, len) {
            return Some(Trap::OutOfBounds { pc: self.ctx.pc, addr, len });
        }
        None
    }

    #[inline]
    fn ready_of(&self, class: RegClass, r: u8) -> u64 {
        match class {
            RegClass::X => {
                if r == 0 {
                    0
                } else {
                    self.ready_x[r as usize]
                }
            }
            RegClass::F => self.ready_f[r as usize],
            RegClass::P => self.ready_p[r as usize],
            RegClass::None => 0,
        }
    }

    #[inline]
    fn set_ready(&mut self, class: RegClass, r: u8, t: u64) {
        match class {
            RegClass::X => {
                if r != 0 {
                    self.ready_x[r as usize] = t;
                }
            }
            RegClass::F => self.ready_f[r as usize] = t,
            RegClass::P => self.ready_p[r as usize] = t,
            RegClass::None => {}
        }
    }

    /// Execute one instruction; returns false when halted (ECALL/EBREAK or
    /// PC past the end of the text segment).
    ///
    /// This is the timing/semantics **oracle**: the superblock engine in
    /// [`block`] must stay bit-and-count identical to it on every program
    /// (pinned by `tests/engine_diff.rs`). Keep it verbatim — performance
    /// work belongs in the block engine.
    pub fn step(&mut self) -> bool {
        if self.halted {
            return false;
        }
        if self.ctx.pc % 4 != 0 {
            // A torn jump target: nothing fetches, nothing issues.
            self.halted = true;
            self.trap = Some(Trap::MisalignedPc { pc: self.ctx.pc });
            self.traps += 1;
            return false;
        }
        let idx = (self.ctx.pc / 4) as usize;
        let Some(&ins) = self.program.get(idx) else {
            self.halted = true;
            self.halt_exit = true;
            return false;
        };
        // NOTE (§Perf): a pre-resolved per-instruction metadata variant was
        // tried and measured ~8% *slower* (fatter per-step footprint) — the
        // static-table lookup below is already cache-resident. The win that
        // finally landed amortizes per *block*, not per instruction: see
        // [`block`] for why, and for the fast path this loop anchors.
        let pi = info(ins.op);

        // ── Issue: wait for operands (RAW) and the functional unit. ─────
        let mut t = self.cycle;
        let t_ops = self
            .ready_of(pi.rs1, ins.rs1)
            .max(self.ready_of(pi.rs2, ins.rs2))
            .max(self.ready_of(pi.rs3, ins.rs3));
        if t_ops > t {
            self.raw_stalls += t_ops - t;
            t = t_ops;
        }
        let uf = self.unit_free[pi.unit as usize];
        if uf > t {
            self.unit_stalls += uf - t;
            t = uf;
        }

        // ── Execute functionally. ───────────────────────────────────────
        let eff = self.exec(&ins);

        // ── Trap? Latch it as a recoverable halt: the faulting
        // instruction issued (its stalls are real) but does not retire —
        // no write-back, no PC advance, no instret.
        if let Some(trap) = eff.trap {
            self.cycle = t + 1;
            self.halted = true;
            self.halt_exit = false;
            self.trap = Some(trap);
            self.traps += 1;
            return false;
        }

        // ── Write-back timing. ──────────────────────────────────────────
        let lat = pi.latency_for(ins.fmt) + eff.mem_extra;
        self.set_ready(pi.rd, ins.rd, t + lat);
        // Non-pipelined units block until the result is produced (§4.1);
        // ALU/LSU/Branch/CSR accept one op per cycle (the LSU blocks for
        // the duration of a miss — single outstanding miss, as in CVA6's
        // blocking D$ port). The quire spill/restore pair holds the port
        // for its whole width-scaled multi-beat walk: exactly the
        // `latency_for` value (`lat` already folds in the miss penalties),
        // so the op-table latency is the one tuning knob for switch cost.
        self.unit_free[pi.unit as usize] = match pi.unit {
            Unit::Pau | Unit::Fpu | Unit::Mul => t + lat,
            Unit::Lsu if matches!(ins.op, Op::Qlq | Op::Qsq) => t + lat,
            Unit::Lsu => t + 1 + eff.mem_extra,
            _ => t + 1,
        };

        // ── Control flow + next cycle. ──────────────────────────────────
        self.cycle = t + 1;
        let next_seq = self.ctx.pc.wrapping_add(4);
        if pi.unit == Unit::Branch {
            // Static BTFN prediction; JAL is always predicted (direct,
            // BTB hit); JALR is modelled as always mispredicted (no RAS).
            let taken = eff.taken;
            let target = eff.next_pc.unwrap_or(next_seq);
            let predicted_target = match ins.op {
                crate::isa::Op::Jal => target,
                crate::isa::Op::Jalr => next_seq,
                _ => {
                    if ins.imm < 0 {
                        self.ctx.pc.wrapping_add(ins.imm as u64)
                    } else {
                        next_seq
                    }
                }
            };
            let actual = if taken { target } else { next_seq };
            if actual != predicted_target {
                self.mispredicts += 1;
                self.cycle += self.cfg.mispredict_penalty;
            }
            self.ctx.pc = actual;
        } else {
            self.ctx.pc = eff.next_pc.unwrap_or(next_seq);
        }

        self.instret += 1;
        if eff.halt {
            self.halted = true;
            self.halt_exit = true;
        }
        if self.cfg.max_instrs != 0 && self.instret >= self.cfg.max_instrs {
            self.halted = true;
        }
        !self.halted
    }

    /// Run until halt on the configured engine; returns the run's stats.
    pub fn run(&mut self) -> Stats {
        match self.cfg.engine {
            Engine::Superblock => self.run_superblock(),
            Engine::Oracle => while self.step() {},
            Engine::Translated => self.run_translated(),
        }
        self.finish_run()
    }

    /// Run until halt on the per-instruction oracle, regardless of the
    /// configured engine — the reference side of every differential.
    pub fn run_oracle(&mut self) -> Stats {
        while self.step() {}
        self.finish_run()
    }

    fn finish_run(&mut self) -> Stats {
        // Account for in-flight results draining (the scoreboard's last
        // write-back defines completion).
        let drain = self
            .ready_x
            .iter()
            .chain(self.ready_f.iter())
            .chain(self.ready_p.iter())
            .copied()
            .max()
            .unwrap_or(0);
        self.cycle = self.cycle.max(drain);
        self.stats()
    }

    pub fn stats(&self) -> Stats {
        Stats {
            cycles: self.cycle,
            instret: self.instret,
            raw_stall_cycles: self.raw_stalls,
            unit_stall_cycles: self.unit_stalls,
            mispredicts: self.mispredicts,
            dcache_hits: self.dcache.hits,
            dcache_misses: self.dcache.misses,
            // Scheduler-level counters: a bare core run has none; the
            // multi-hart scheduler fills them on its per-hart reports.
            ctx_switches: 0,
            spill_cycles: 0,
            traps: self.traps,
            checkpoints: 0,
            migrations: 0,
            retries: 0,
            deadline_misses: 0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::asm::assemble;
    use crate::posit::Posit32;

    fn run_src(src: &str) -> Core {
        let prog = assemble(src).expect("assembles");
        let mut core = Core::new(CoreConfig { mem_size: 1 << 20, ..Default::default() });
        core.load_program(&prog);
        core.run();
        core
    }

    #[test]
    fn arithmetic_loop() {
        // Sum 10+9+…+1 into a0.
        let core = run_src(
            r#"
            li a0, 0
            li a1, 10
        loop:
            add a0, a0, a1
            addi a1, a1, -1
            bnez a1, loop
            ecall
        "#,
        );
        assert_eq!(core.ctx.x[10], 55);
        assert!(core.halted());
    }

    #[test]
    fn memory_roundtrip_and_loadstore_classes() {
        let core = run_src(
            r#"
            li a0, 0x100
            li t0, -7
            sd t0, 0(a0)
            ld t1, 0(a0)
            sw t0, 8(a0)
            lw t2, 8(a0)
            lwu t3, 8(a0)
            ecall
        "#,
        );
        assert_eq!(core.ctx.x[6] as i64, -7);
        assert_eq!(core.ctx.x[7] as i64, -7); // lw sign-extends
        assert_eq!(core.ctx.x[28], 0xFFFF_FFF9); // lwu zero-extends
    }

    #[test]
    fn float_fmadd_matches_host() {
        let core = run_src(
            r#"
            li a0, 0x100
            li t0, 0x40490fdb      # 3.14159274 f32
            sw t0, 0(a0)
            flw ft0, 0(a0)
            fmadd.s ft1, ft0, ft0, ft0
            fsw ft1, 4(a0)
            ecall
        "#,
        );
        let x = f32::from_bits(0x40490fdb);
        let expect = x.mul_add(x, x);
        assert_eq!(core.mem.read_u32(0x104), expect.to_bits());
    }

    #[test]
    fn posit_quire_dot_product() {
        // p-dot of [1,2,3]·[4,5,6] = 32 via the quire.
        let a: Vec<u32> = [1.0, 2.0, 3.0].iter().map(|v| Posit32::from_f64(*v).bits()).collect();
        let b: Vec<u32> = [4.0, 5.0, 6.0].iter().map(|v| Posit32::from_f64(*v).bits()).collect();
        let prog = assemble(
            r#"
            li a0, 0x100
            li a1, 0x200
            li a2, 3
            qclr.s
        loop:
            plw p0, 0(a0)
            plw p1, 0(a1)
            qmadd.s p0, p1
            addi a0, a0, 4
            addi a1, a1, 4
            addi a2, a2, -1
            bnez a2, loop
            qround.s p2
            psw p2, 0(a3)
            ecall
        "#,
        )
        .unwrap();
        let mut core = Core::new(CoreConfig { mem_size: 1 << 20, ..Default::default() });
        core.load_program(&prog);
        core.mem.write_u32_slice(0x100, &a);
        core.mem.write_u32_slice(0x200, &b);
        core.ctx.x[13] = 0x300;
        core.run();
        assert_eq!(Posit32(core.mem.read_u32(0x300)).to_f64(), 32.0);
    }

    #[test]
    fn raw_hazard_stalls_accumulator_chain() {
        // Dependent fadd.s chain: each op waits for the previous result
        // (latency 3) AND the non-pipelined FPU, so 10 ops ≳ 30 cycles.
        let src = "fadd.s ft0, ft0, ft1\n".repeat(10) + "ecall";
        let core = run_src(&src);
        assert!(core.cycle >= 30, "cycle = {}", core.cycle);
        let s = core.stats();
        assert!(s.raw_stall_cycles + s.unit_stall_cycles >= 18);
    }

    #[test]
    fn independent_alu_ops_are_one_per_cycle() {
        let core = run_src(
            r#"
            addi a0, zero, 1
            addi a1, zero, 2
            addi a2, zero, 3
            addi a3, zero, 4
            addi a4, zero, 5
            addi a5, zero, 6
            ecall
        "#,
        );
        // 7 instructions, no stalls → ~7 cycles (+ drain 0).
        assert!(core.cycle <= 8, "cycle = {}", core.cycle);
        assert_eq!(core.stats().raw_stall_cycles, 0);
    }

    #[test]
    fn dcache_miss_penalty_charged() {
        // Two loads to the same line: first misses, second hits.
        let core = run_src(
            r#"
            li a0, 0x1000
            lw t0, 0(a0)
            lw t1, 4(a0)
            ecall
        "#,
        );
        let s = core.stats();
        assert_eq!(s.dcache_misses, 1);
        assert_eq!(s.dcache_hits, 1);
    }

    #[test]
    fn loop_branches_predicted_taken() {
        // A hot loop should mispredict ~once (the exit).
        let core = run_src(
            r#"
            li a1, 100
        loop:
            addi a1, a1, -1
            bnez a1, loop
            ecall
        "#,
        );
        assert_eq!(core.stats().mispredicts, 1);
    }

    #[test]
    fn posit_compares_zero_latency_vs_fpu() {
        // Same dependent compare chain in posit (ALU) vs float (FPU):
        // the posit version must finish in fewer cycles (§7.2's max-pool
        // result in miniature).
        let psrc = r#"
            pmax.s p0, p0, p1
            pmax.s p0, p0, p2
            pmax.s p0, p0, p3
            pmax.s p0, p0, p4
            pmax.s p0, p0, p5
            ecall
        "#;
        let fsrc = r#"
            fmax.s ft0, ft0, ft1
            fmax.s ft0, ft0, ft2
            fmax.s ft0, ft0, ft3
            fmax.s ft0, ft0, ft4
            fmax.s ft0, ft0, ft5
            ecall
        "#;
        let p = run_src(psrc).cycle;
        let f = run_src(fsrc).cycle;
        assert!(p < f, "posit {p} vs float {f}");
    }

    #[test]
    fn rdcycle_reads_counter() {
        let core = run_src(
            r#"
            rdcycle a0
            addi a1, zero, 1
            addi a1, zero, 2
            rdcycle a2
            ecall
        "#,
        );
        assert!(core.ctx.x[12] > core.ctx.x[10]);
    }

    #[test]
    fn multiwidth_loads_stores_roundtrip() {
        // plb/plh/plw/pld and psb/psh/psw/psd move 1/2/4/8-byte posit
        // patterns through the D$ model without mangling bits.
        let mut core = Core::new(CoreConfig { mem_size: 1 << 20, ..Default::default() });
        let prog = assemble(
            r#"
            li a0, 0x100
            plb p0, 0(a0)
            psb p0, 64(a0)
            plh p1, 2(a0)
            psh p1, 66(a0)
            plw p2, 4(a0)
            psw p2, 68(a0)
            pld p3, 8(a0)
            psd p3, 72(a0)
            ecall
        "#,
        )
        .unwrap();
        core.load_program(&prog);
        core.mem.write_u8(0x100, 0xA5);
        core.mem.write_u16(0x102, 0xBEEF);
        core.mem.write_u32(0x104, 0xDEAD_BEEF);
        core.mem.write_u64(0x108, 0x0123_4567_89AB_CDEF);
        core.run();
        assert_eq!(core.ctx.p[0], 0xA5);
        assert_eq!(core.ctx.p[1], 0xBEEF);
        assert_eq!(core.ctx.p[2], 0xDEAD_BEEF);
        assert_eq!(core.ctx.p[3], 0x0123_4567_89AB_CDEF);
        assert_eq!(core.mem.read_u8(0x140), 0xA5);
        assert_eq!(core.mem.read_u16(0x142), 0xBEEF);
        assert_eq!(core.mem.read_u32(0x144), 0xDEAD_BEEF);
        assert_eq!(core.mem.read_u64(0x148), 0x0123_4567_89AB_CDEF);
    }

    #[test]
    fn posit16_quire_dot_product() {
        // The Fig. 6 dot loop at 16 bits: [1,2,3]·[4,5,6] = 32.
        use crate::posit::Posit16;
        let a: Vec<u64> =
            [1.0, 2.0, 3.0].iter().map(|v| Posit16::from_f64(*v).bits() as u64).collect();
        let b: Vec<u64> =
            [4.0, 5.0, 6.0].iter().map(|v| Posit16::from_f64(*v).bits() as u64).collect();
        let prog = assemble(
            r#"
            li a0, 0x100
            li a1, 0x200
            li a2, 3
            qclr.h
        loop:
            plh p0, 0(a0)
            plh p1, 0(a1)
            qmadd.h p0, p1
            addi a0, a0, 2
            addi a1, a1, 2
            addi a2, a2, -1
            bnez a2, loop
            qround.h p2
            psh p2, 0(a3)
            ecall
        "#,
        )
        .unwrap();
        let mut core = Core::new(CoreConfig { mem_size: 1 << 20, ..Default::default() });
        core.load_program(&prog);
        core.mem.write_posit_slice(0x100, 2, &a);
        core.mem.write_posit_slice(0x200, 2, &b);
        core.ctx.x[13] = 0x300;
        core.run();
        assert_eq!(Posit16::from_bits(core.mem.read_u16(0x300) as u32).to_f64(), 32.0);
    }

    #[test]
    fn posit64_quire_dot_product() {
        // The same loop at 64 bits through the 1024-bit PauQuire::Q64.
        use crate::posit::Posit64;
        let a: Vec<u64> = [1.5, -2.0, 3.25].iter().map(|v| Posit64::from_f64(*v).bits()).collect();
        let b: Vec<u64> = [4.0, 0.5, -6.0].iter().map(|v| Posit64::from_f64(*v).bits()).collect();
        let expect = 1.5 * 4.0 + -2.0 * 0.5 + 3.25 * -6.0;
        let prog = assemble(
            r#"
            li a0, 0x100
            li a1, 0x200
            li a2, 3
            qclr.d
        loop:
            pld p0, 0(a0)
            pld p1, 0(a1)
            qmadd.d p0, p1
            addi a0, a0, 8
            addi a1, a1, 8
            addi a2, a2, -1
            bnez a2, loop
            qround.d p2
            psd p2, 0(a3)
            ecall
        "#,
        )
        .unwrap();
        let mut core = Core::new(CoreConfig { mem_size: 1 << 20, ..Default::default() });
        core.load_program(&prog);
        core.mem.write_posit_slice(0x100, 8, &a);
        core.mem.write_posit_slice(0x200, 8, &b);
        core.ctx.x[13] = 0x300;
        core.run();
        assert!(matches!(core.ctx.quire, PauQuire::Q64(_)));
        assert_eq!(Posit64::from_bits(core.mem.read_u64(0x300)).to_f64(), expect);
    }

    #[test]
    fn quire_retags_on_width_switch() {
        // Switching quire width re-purposes the accumulator: the stale
        // 32-bit contents must not leak into the 8-bit round.
        let core = run_src(
            r#"
            qclr.s
            pcvt.s.w p0, zero
            pcvt.b.w p1, zero
            qclr.b
            qround.b p3
            ecall
        "#,
        );
        assert!(matches!(core.ctx.quire, PauQuire::Q8(_)));
        assert_eq!(core.ctx.p[3], 0, "cleared 8-bit quire rounds to zero");
    }

    #[test]
    fn p64_quire_ops_are_slower_than_p32() {
        // Width-scaled latencies: the same dependent qmadd chain takes
        // longer at 64 bits (+2 cycles per quire op through the PAU).
        let p32 = "qclr.s\n".to_string() + &"qmadd.s p0, p1\n".repeat(8) + "ecall";
        let p64 = "qclr.d\n".to_string() + &"qmadd.d p0, p1\n".repeat(8) + "ecall";
        let t32 = run_src(&p32).cycle;
        let t64 = run_src(&p64).cycle;
        assert!(t64 > t32, "p64 {t64} !> p32 {t32}");
        // 8 qmadds × (3 + 2) = 40 cycles minimum through the PAU.
        assert!(t64 >= 40, "cycle = {t64}");
    }

    #[test]
    fn quire_serialises_through_pau() {
        // Back-to-back qmadd.s with no other deps still cannot exceed one
        // per PADD-class latency (non-pipelined PAU).
        let src = "qclr.s\n".to_string() + &"qmadd.s p0, p1\n".repeat(8) + "ecall";
        let core = run_src(&src);
        // 8 qmadds × latency 3 = 24 cycles minimum through the PAU.
        assert!(core.cycle >= 24, "cycle = {}", core.cycle);
    }

    #[test]
    fn quire_spill_roundtrips_bit_identically_every_width() {
        // qsq writes exactly `Quire::to_bytes` through the simulated D$,
        // and qlq restores it bit-identically: accumulate, spill, wipe,
        // restore, keep accumulating — the result must match a native
        // PauQuire driven the same way.
        use crate::posit::convert::from_f64_n;
        for fmt in PositFmt::ALL {
            let w = fmt.width();
            let (sfx, load) = match fmt {
                PositFmt::P8 => ("b", "plb"),
                PositFmt::P16 => ("h", "plh"),
                PositFmt::P32 => ("s", "plw"),
                PositFmt::P64 => ("d", "pld"),
            };
            let eb = fmt.bytes();
            let a = from_f64_n(w, -2.75);
            let b = from_f64_n(w, 1.5);
            let src = format!(
                r#"
                li a0, 0x100
                li a1, 0x400
                {load} p0, 0(a0)
                {load} p1, {eb}(a0)
                qclr.{sfx}
                qmadd.{sfx} p0, p1
                qsq.{sfx} (a1)
                qclr.{sfx}
                qlq.{sfx} (a1)
                qmsub.{sfx} p0, p1
                qround.{sfx} p2
                ecall
            "#
            );
            let prog = assemble(&src).unwrap();
            let mut core = Core::new(CoreConfig { mem_size: 1 << 20, ..Default::default() });
            core.load_program(&prog);
            core.mem.write_posit_slice(0x100, eb, &[a, b]);
            core.run();
            // Native reference: the same sequence on a PauQuire.
            let mut q = PauQuire::new(fmt);
            q.clear(fmt);
            q.madd(fmt, a, b);
            let img = q.spill(fmt);
            assert_eq!(
                core.mem.read_bytes(0x400, fmt.quire_bytes()),
                &img[..],
                "{fmt:?}: spilled image != Quire::to_bytes"
            );
            let mut r = PauQuire::restore(fmt, &img);
            r.msub(fmt, a, b);
            assert_eq!(core.ctx.quire, r, "{fmt:?}: restored quire diverges");
            // madd then msub of the same product cancels exactly.
            assert_eq!(core.ctx.p[2], 0, "{fmt:?}: round after cancel");
        }
    }

    #[test]
    fn quire_spill_nar_image_is_canonical() {
        // A NaR quire spills as the standard's canonical 10…0 image and
        // restores sticky-NaR: qround after the restore must give NaR.
        let prog = assemble(
            r#"
            li a0, 0x400
            qclr.h
            pmv.h.x p0, zero
            qmadd.h p0, p1
            qsq.h (a0)
            qclr.h
            qlq.h (a0)
            qround.h p3
            ecall
        "#,
        )
        .unwrap();
        let mut core = Core::new(CoreConfig { mem_size: 1 << 20, ..Default::default() });
        core.load_program(&prog);
        core.ctx.p[1] = 0x8000; // Posit16 NaR operand
        core.run();
        let img = core.mem.read_bytes(0x400, PositFmt::P16.quire_bytes());
        assert_eq!(img[31], 0x80, "NaR image top byte");
        assert!(img[..31].iter().all(|&b| b == 0), "NaR image is 10…0");
        assert_eq!(core.ctx.p[3], 0x8000, "restored NaR rounds to NaR");
    }

    #[test]
    fn quire_spill_retags_like_other_quire_ops() {
        // Spilling at a width other than the live accumulation re-tags
        // (and therefore clears) first, like hardware re-purposing the
        // one physical register; restoring at the instruction width tags
        // the accumulator to it.
        let core = run_src(
            r#"
            li a0, 0x400
            qclr.s
            pcvt.s.w p0, zero
            qsq.b (a0)
            qlq.b (a0)
            ecall
        "#,
        );
        assert!(matches!(core.ctx.quire, PauQuire::Q8(_)));
        let img = core.mem.read_bytes(0x400, PositFmt::P8.quire_bytes());
        assert!(img.iter().all(|&b| b == 0), "cross-width spill is the cleared image");
    }

    #[test]
    fn quire_spill_costs_scale_with_width() {
        // The 1024-bit Posit64 image takes 8× the beats of the 128-bit
        // Posit8 one; back-to-back spills serialize on the LSU, so the
        // wide loop must be measurably slower per iteration.
        let run = |sfx: &str| {
            run_src(&format!("li a0, 0x400\n{}ecall", "qsq.SFX (a0)\n".repeat(8).replace("SFX", sfx)))
        };
        let t8 = run("b").cycle;
        let t64 = run("d").cycle;
        // 8 spills × 16 beats = 128 cycles minimum through the LSU at 64
        // bits vs 8 × 2 = 16 at 8 bits.
        assert!(t64 >= 128, "cycle = {t64}");
        assert!(t64 > t8 + 96, "p64 {t64} !≫ p8 {t8}");
    }

    #[test]
    fn clear_halt_resumes_after_quantum_expiry() {
        // max_instrs is the scheduler's quantum: the halt it causes is
        // not a program exit, and clear_halt resumes mid-program (even
        // mid-fused-loop) to the identical final state.
        let src = r#"
            li a0, 0x100
            li a1, 0x200
            li a2, 100
        loop:
            plw p0, 0(a0)
            plw p1, 0(a1)
            qmadd.s p0, p1
            addi a0, a0, 4
            addi a1, a1, 4
            addi a2, a2, -1
            bnez a2, loop
            ecall
        "#;
        let prog = assemble(src).unwrap();
        let run_chunked = |chunk: u64| {
            let mut core =
                Core::new(CoreConfig { mem_size: 1 << 20, ..Default::default() });
            core.load_program(&prog);
            loop {
                core.cfg.max_instrs = core.instret + chunk;
                core.run();
                if core.halted_on_exit() {
                    break;
                }
                assert!(core.halted(), "run returned without halting");
                core.clear_halt();
            }
            (core.stats().instret, core.ctx.clone())
        };
        let (i1, ctx1) = run_chunked(7);
        let (i2, ctx2) = run_chunked(1_000_000);
        assert_eq!(i1, i2, "instruction count diverges across quanta");
        assert_eq!(ctx1, ctx2, "architectural state diverges across quanta");
    }

    #[test]
    fn context_save_restore_swaps_jobs() {
        // Two programs time-sliced on one core through save/restore:
        // each must end exactly as if it ran alone.
        let p1 = assemble("li a0, 1\nli a1, 2\nadd a0, a0, a1\necall").unwrap();
        let p2 = assemble("li a0, 40\nli a1, 2\nadd a0, a0, a1\necall").unwrap();
        let mut core = Core::new(CoreConfig { mem_size: 1 << 20, ..Default::default() });
        // Run p1 for one instruction, park it, run p2 fully, resume p1.
        core.load_program(&p1);
        core.cfg.max_instrs = 1;
        core.run();
        assert!(!core.halted_on_exit());
        let parked = core.save_context();
        core.cfg.max_instrs = 0;
        core.load_program(&p2);
        core.restore_context(HartContext::new());
        core.run();
        assert!(core.halted_on_exit());
        assert_eq!(core.ctx.x[10], 42);
        core.load_program(&p1);
        core.restore_context(parked);
        core.run();
        assert!(core.halted_on_exit());
        assert_eq!(core.ctx.x[10], 3);
    }

    #[test]
    fn load_program_shares_text_segment() {
        // The Arc-backed program store: loading must not copy the text
        // segment (coordinator batch runs re-load kernels per job).
        let prog = assemble("ecall").unwrap();
        let mut core = Core::new(CoreConfig { mem_size: 1 << 20, ..Default::default() });
        core.load_program(&prog);
        assert!(Arc::ptr_eq(&core.program, &prog.instrs));
    }

    #[test]
    fn plan_cache_survives_alternating_loads() {
        // The context-switch pattern: job kernel ↔ 2-instruction switch
        // kernel. Swapping back must reuse the cached plan, not rebuild.
        let p1 = assemble("addi a0, a0, 1\necall").unwrap();
        let p2 = assemble("qsq.s (a0)\necall").unwrap();
        let mut core = Core::new(CoreConfig { mem_size: 1 << 20, ..Default::default() });
        core.load_program(&p1);
        let plan1 = Arc::as_ptr(&core.plan);
        core.load_program(&p2);
        core.load_program(&p1);
        assert_eq!(Arc::as_ptr(&core.plan), plan1, "plan rebuilt despite the cache");
    }

    #[test]
    fn superblock_engine_matches_oracle() {
        // Fused MAC loop, branchy scalar code, and a JALR landing
        // mid-block (the step() fallback) — each must be stats- and
        // state-identical across the two engines.
        let dot = r#"
            li a0, 0x100
            li a1, 0x200
            li a2, 5
            qclr.s
        loop:
            plw p0, 0(a0)
            plw p1, 0(a1)
            qmadd.s p0, p1
            addi a0, a0, 4
            addi a1, a1, 4
            addi a2, a2, -1
            bnez a2, loop
            qround.s p2
            psw p2, 0(a3)
            ecall
        "#;
        let scalar = r#"
            li a1, 37
            li a2, 0
        loop:
            andi t0, a1, 1
            beqz t0, even
            addi a2, a2, 3
        even:
            srli a1, a1, 1
            bnez a1, loop
            ecall
        "#;
        let jalr = r#"
            jalr ra, 16(zero)
            addi a0, a0, 1
            ecall
            addi t0, zero, 9
            addi a0, a0, 7
            jr ra
        "#;
        for src in [dot, scalar, jalr] {
            let prog = assemble(src).unwrap();
            let mut cores: Vec<Core> = [Engine::Superblock, Engine::Oracle]
                .into_iter()
                .map(|engine| {
                    let mut c = Core::new(CoreConfig {
                        mem_size: 1 << 20,
                        engine,
                        ..Default::default()
                    });
                    c.load_program(&prog);
                    let vals: Vec<u32> = (0..8)
                        .map(|i| Posit32::from_f64(i as f64 * 0.75 - 2.0).bits())
                        .collect();
                    c.mem.write_u32_slice(0x100, &vals);
                    c.mem.write_u32_slice(0x200, &vals);
                    c.ctx.x[13] = 0x300;
                    c
                })
                .collect();
            let s_sb = cores[0].run();
            let s_or = cores[1].run();
            assert_eq!(s_sb, s_or, "stats diverge");
            assert_eq!(cores[0].ctx, cores[1].ctx);
            assert_eq!(cores[0].mem.bytes(), cores[1].mem.bytes());
        }
    }

    #[test]
    fn max_instrs_trips_identically_inside_fused_loop() {
        // The safety valve must halt both engines at the same instruction
        // even when it fires mid-way through a fused loop iteration.
        let src = r#"
            li a0, 0x100
            li a1, 0x200
            li a2, 1000
        loop:
            plw p0, 0(a0)
            plw p1, 0(a1)
            qmadd.s p0, p1
            addi a0, a0, 4
            addi a1, a1, 4
            addi a2, a2, -1
            bnez a2, loop
            ecall
        "#;
        let prog = assemble(src).unwrap();
        for cap in [25u64, 26, 27, 28, 29, 30, 31, 32] {
            let run = |engine| {
                let mut c = Core::new(CoreConfig {
                    mem_size: 1 << 20,
                    max_instrs: cap,
                    engine,
                    ..Default::default()
                });
                c.load_program(&prog);
                let s = c.run();
                assert!(c.halted());
                (s, c.ctx.clone())
            };
            assert_eq!(run(Engine::Superblock), run(Engine::Oracle), "cap {cap}");
        }
    }

    #[test]
    fn oob_access_traps_identically_on_both_engines() {
        // A wild load halts with a typed trap instead of panicking; the
        // faulting instruction does not retire and writes nothing, and
        // both engines agree on stats, cause, and state.
        let prog = assemble("lw t0, 0(a0)\naddi a2, zero, 7\necall").unwrap();
        let run = |engine| {
            let mut c = Core::new(CoreConfig { mem_size: 4096, engine, ..Default::default() });
            c.load_program(&prog);
            c.ctx.x[10] = 1 << 20; // far past the 4 KiB memory
            let s = c.run();
            (s, c.halt_cause(), c.ctx.clone())
        };
        let (s_sb, cause_sb, ctx_sb) = run(Engine::Superblock);
        let (s_or, cause_or, ctx_or) = run(Engine::Oracle);
        assert_eq!(s_sb, s_or);
        assert_eq!(cause_sb, cause_or);
        assert_eq!(ctx_sb, ctx_or);
        assert_eq!(s_sb.traps, 1);
        assert_eq!(s_sb.instret, 0, "the faulting lw does not retire");
        assert_eq!(ctx_sb.x[5], 0, "no write-back");
        assert_eq!(ctx_sb.x[12], 0, "nothing after the trap runs");
        assert_eq!(ctx_sb.pc, 0, "pc stays at the faulting instruction");
        assert!(matches!(cause_sb, Some(HaltCause::Trap(Trap::OutOfBounds { .. }))));
    }

    #[test]
    fn misaligned_store_traps_without_memory_effect() {
        let prog = assemble("addi t1, zero, 9\nsd t1, 0(a0)\necall").unwrap();
        let mut c = Core::new(CoreConfig { mem_size: 4096, ..Default::default() });
        c.load_program(&prog);
        c.ctx.x[10] = 0x101; // 8-byte store, odd address
        c.run();
        assert!(matches!(
            c.trap(),
            Some(Trap::Misaligned { addr: 0x101, len: 8, .. })
        ));
        assert!(c.mem.bytes().iter().all(|&b| b == 0), "store must not land");
        assert!(!c.halted_on_exit());
    }

    #[test]
    fn illegal_opcode_traps_via_synthetic_stream() {
        // The decoder never produces Op::Illegal; synthetic streams (the
        // fuzzer, fault injection) place it directly.
        let instrs: Arc<[Instr]> =
            vec![Instr::r(Op::Illegal, 0, 0, 0), Instr::r(Op::Ecall, 0, 0, 0)].into();
        for engine in [Engine::Superblock, Engine::Oracle] {
            let mut c = Core::new(CoreConfig { mem_size: 4096, engine, ..Default::default() });
            c.load_instrs(Arc::clone(&instrs));
            let s = c.run();
            assert_eq!(s.instret, 0, "{engine:?}");
            assert_eq!(s.traps, 1, "{engine:?}");
            assert_eq!(
                c.halt_cause(),
                Some(HaltCause::Trap(Trap::IllegalInstruction { pc: 0 })),
                "{engine:?}"
            );
        }
    }

    #[test]
    fn halt_cause_distinguishes_exit_quantum_trap() {
        // Exit.
        let mut c = run_src("ecall");
        assert_eq!(c.halt_cause(), Some(HaltCause::Exit));
        // Quantum.
        let prog = assemble("loop: j loop").unwrap();
        c = Core::new(CoreConfig { mem_size: 4096, max_instrs: 10, ..Default::default() });
        c.load_program(&prog);
        c.run();
        assert_eq!(c.halt_cause(), Some(HaltCause::Quantum));
        // clear_halt clears the cause; a fresh load clears a trap.
        c.clear_halt();
        assert_eq!(c.halt_cause(), None);
    }

    #[test]
    fn context_image_roundtrips_and_validates() {
        // Rich state: dirty quire, patterned registers.
        let mut ctx = HartContext::new();
        ctx.pc = 0x44;
        for i in 0..32 {
            ctx.x[i] = i as u64 * 3;
            ctx.f[i] = (i as u64) << 32;
            ctx.p[i] = !(i as u64);
        }
        ctx.quire.madd(PositFmt::P32, 0x4000_0000, 0x4000_0000);
        let img = ctx.to_image();
        assert_eq!(HartContext::from_image(&img).unwrap(), ctx);
        // Truncation, corruption, and a wrong version are typed errors.
        assert!(HartContext::from_image(&img[..img.len() - 1]).is_err());
        let mut bad = img.clone();
        bad[100] ^= 0x40;
        assert!(HartContext::from_image(&bad).is_err(), "checksum must catch flips");
        let mut wrong_ver = img.clone();
        wrong_ver[4] = 0xFF;
        assert!(HartContext::from_image(&wrong_ver).is_err());
        let mut wrong_magic = img;
        wrong_magic[0] = b'X';
        assert!(HartContext::from_image(&wrong_magic).is_err());
    }
}
