//! Max-pooling workloads (paper §7.2, Table 8): LeNet-5, AlexNet and
//! ResNet-50 pooling layers in f32 / f64 / posit32.
//!
//! The posit kernel uses `pmax.s`, which PERCIVAL executes on the integer
//! ALU with no latency (§2.1/§4.2) — the paper's point is that posits get
//! max-pooling "for free" while floats pay the FPU compare latency.

use crate::core::{Core, CoreConfig, Stats};
use crate::isa::asm::{assemble, Program};
use crate::posit::Posit32;
use crate::testing::Rng;

/// Pooling layer configuration.
#[derive(Debug, Clone, Copy)]
pub struct PoolConfig {
    pub name: &'static str,
    pub c: usize,
    pub h: usize,
    pub w: usize,
    pub k: usize,
    pub s: usize,
}

impl PoolConfig {
    /// The paper's three layers (Table 8).
    pub const LENET5: PoolConfig =
        PoolConfig { name: "LeNet-5 (28x28x6)", c: 6, h: 28, w: 28, k: 2, s: 2 };
    pub const ALEXNET: PoolConfig =
        PoolConfig { name: "AlexNet (54x54x96)", c: 96, h: 54, w: 54, k: 3, s: 2 };
    pub const RESNET50: PoolConfig =
        PoolConfig { name: "ResNet-50 (112x112x64)", c: 64, h: 112, w: 112, k: 3, s: 2 };
    pub const ALL: [PoolConfig; 3] = [Self::LENET5, Self::ALEXNET, Self::RESNET50];

    pub fn out_h(&self) -> usize {
        (self.h - self.k) / self.s + 1
    }

    pub fn out_w(&self) -> usize {
        (self.w - self.k) / self.s + 1
    }

    pub fn in_len(&self) -> usize {
        self.c * self.h * self.w
    }

    pub fn out_len(&self) -> usize {
        self.c * self.out_h() * self.out_w()
    }
}

/// Number format for the pooling kernel.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PoolFormat {
    F32,
    F64,
    P32,
}

impl PoolFormat {
    pub const ALL: [PoolFormat; 3] = [PoolFormat::F32, PoolFormat::F64, PoolFormat::P32];

    pub fn label(&self) -> &'static str {
        match self {
            PoolFormat::F32 => "32-bit float",
            PoolFormat::F64 => "64-bit float",
            PoolFormat::P32 => "Posit32",
        }
    }

    pub fn elem_bytes(&self) -> usize {
        match self {
            PoolFormat::F64 => 8,
            _ => 4,
        }
    }
}

/// Generate the pooling kernel: fully unrolled k×k window, strength-reduced
/// pointers (the natural `-O2` shape of the paper's C benchmark).
/// Calling convention: `a0 = &input (CHW)`, `a1 = &output`.
pub fn maxpool_program(fmt: PoolFormat, cfg: &PoolConfig) -> Program {
    let eb = fmt.elem_bytes();
    let (load0, loadi, maxi, store) = match fmt {
        PoolFormat::F32 => ("flw ft0, 0(s4)", "flw", "fmax.s ft0, ft0, ft1", "fsw ft0, 0(t4)"),
        PoolFormat::F64 => ("fld ft0, 0(s4)", "fld", "fmax.d ft0, ft0, ft1", "fsd ft0, 0(t4)"),
        PoolFormat::P32 => ("plw p0, 0(s4)", "plw", "pmax.s p0, p0, p1", "psw p0, 0(t4)"),
    };
    let tmp = match fmt {
        PoolFormat::P32 => "p1",
        _ => "ft1",
    };
    // Unrolled window body: first element initialises the accumulator.
    let mut window = String::new();
    window.push_str(&format!("    {load0}\n"));
    for r in 0..cfg.k {
        for c in 0..cfg.k {
            if r == 0 && c == 0 {
                continue;
            }
            let off = (r * cfg.w + c) * eb;
            window.push_str(&format!("    {loadi} {tmp}, {off}(s4)\n    {maxi}\n"));
        }
    }
    let src = format!(
        r#"
    # max-pool {fmt:?} {name} k={k} s={s}
    li   t5, {row_step}     # s·w·eb: input row-group step per output row
    li   t6, {chan_step}    # h·w·eb: channel step
    li   s0, {c}            # channel counter
    mv   s5, a0             # channel base
    mv   t4, a1             # output pointer
loop_c:
    li   s1, {oh}
    mv   s3, s5
loop_oh:
    li   s2, {ow}
    mv   s4, s3
loop_ow:
{window}    {store}
    addi t4, t4, {eb}
    addi s4, s4, {win_step}
    addi s2, s2, -1
    bnez s2, loop_ow
    add  s3, s3, t5
    addi s1, s1, -1
    bnez s1, loop_oh
    add  s5, s5, t6
    addi s0, s0, -1
    bnez s0, loop_c
    ecall
"#,
        name = cfg.name,
        k = cfg.k,
        s = cfg.s,
        row_step = cfg.s * cfg.w * eb,
        chan_step = cfg.h * cfg.w * eb,
        c = cfg.c,
        oh = cfg.out_h(),
        ow = cfg.out_w(),
        win_step = cfg.s * eb,
    );
    assemble(&src).expect("generated max-pool kernel must assemble")
}

/// Memory layout: input at 0x1_0000, output page-aligned after it.
pub fn layout(fmt: PoolFormat, cfg: &PoolConfig) -> (u64, u64) {
    let inp = 0x1_0000u64;
    let out = (inp + (cfg.in_len() * fmt.elem_bytes()) as u64 + 0xFFF) & !0xFFF;
    (inp, out)
}

/// Outcome of one simulated pooling layer.
pub struct PoolRun {
    pub stats: Stats,
    pub seconds: f64,
    pub output: Vec<f64>,
}

/// Simulate the pooling layer over a deterministic random input.
pub fn run_pool_sim(core_cfg: CoreConfig, fmt: PoolFormat, cfg: &PoolConfig, warm: bool) -> PoolRun {
    let mut rng = Rng::new(0xDEE7 ^ cfg.c as u64);
    let input: Vec<f64> = (0..cfg.in_len()).map(|_| rng.range_f64(-8.0, 8.0)).collect();
    let prog = maxpool_program(fmt, cfg);
    let mut core = Core::new(core_cfg);
    core.load_program(&prog);
    let (inp, out) = layout(fmt, cfg);
    match fmt {
        PoolFormat::F32 => {
            let v: Vec<f32> = input.iter().map(|x| *x as f32).collect();
            core.mem.write_f32_slice(inp, &v);
        }
        PoolFormat::F64 => core.mem.write_f64_slice(inp, &input),
        PoolFormat::P32 => {
            let v: Vec<u32> = input.iter().map(|x| Posit32::from_f64(*x).bits()).collect();
            core.mem.write_u32_slice(inp, &v);
        }
    }
    let set_args = |core: &mut Core| {
        core.ctx.x[10] = inp;
        core.ctx.x[11] = out;
    };
    if warm {
        set_args(&mut core);
        core.run();
        core.reset_timing();
    }
    set_args(&mut core);
    let stats = core.run();
    let seconds = stats.seconds(&core.cfg);
    let output = match fmt {
        PoolFormat::F32 => {
            core.mem.read_f32_slice(out, cfg.out_len()).iter().map(|v| *v as f64).collect()
        }
        PoolFormat::F64 => core.mem.read_f64_slice(out, cfg.out_len()),
        PoolFormat::P32 => core
            .mem
            .read_u32_slice(out, cfg.out_len())
            .iter()
            .map(|v| Posit32(*v).to_f64())
            .collect(),
    };
    PoolRun { stats, seconds, output }
}

/// Reference pooling on f64 (for correctness checks).
pub fn pool_reference(cfg: &PoolConfig, input: &[f64]) -> Vec<f64> {
    let (oh, ow) = (cfg.out_h(), cfg.out_w());
    let mut out = vec![0.0; cfg.c * oh * ow];
    for c in 0..cfg.c {
        for i in 0..oh {
            for j in 0..ow {
                let mut m = f64::NEG_INFINITY;
                for r in 0..cfg.k {
                    for s in 0..cfg.k {
                        let v = input[(c * cfg.h + i * cfg.s + r) * cfg.w + j * cfg.s + s];
                        m = m.max(v);
                    }
                }
                out[c * oh * ow + i * ow + j] = m;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_kernels_assemble() {
        for fmt in PoolFormat::ALL {
            for cfg in PoolConfig::ALL {
                let p = maxpool_program(fmt, &cfg);
                assert!(p.words.len() > 10, "{fmt:?} {}", cfg.name);
            }
        }
    }

    #[test]
    fn output_dims_match_paper() {
        assert_eq!((PoolConfig::LENET5.out_h(), PoolConfig::LENET5.out_w()), (14, 14));
        assert_eq!((PoolConfig::ALEXNET.out_h(), PoolConfig::ALEXNET.out_w()), (26, 26));
        assert_eq!((PoolConfig::RESNET50.out_h(), PoolConfig::RESNET50.out_w()), (55, 55));
    }

    #[test]
    fn pooling_is_correct_small() {
        // Tiny config for a full functional check against the reference.
        let cfg = PoolConfig { name: "tiny", c: 2, h: 6, w: 6, k: 2, s: 2 };
        let core_cfg = CoreConfig { mem_size: 1 << 20, ..Default::default() };
        // f64 path is exact → must equal reference exactly.
        let run = run_pool_sim(core_cfg, PoolFormat::F64, &cfg, false);
        let mut rng = Rng::new(0xDEE7 ^ cfg.c as u64);
        let input: Vec<f64> = (0..cfg.in_len()).map(|_| rng.range_f64(-8.0, 8.0)).collect();
        let want = pool_reference(&cfg, &input);
        assert_eq!(run.output, want);
        // Posit path: max over *converted* values = converted max (order
        // preservation) — compare against the posit-rounded reference.
        let run = run_pool_sim(core_cfg, PoolFormat::P32, &cfg, false);
        let want_p: Vec<f64> =
            want.iter().map(|v| Posit32::from_f64(*v).to_f64()).collect();
        assert_eq!(run.output, want_p);
    }

    #[test]
    fn posit_as_fast_as_f32_and_f64_slower() {
        // Table 8's shape on the LeNet-5 layer.
        let core_cfg = CoreConfig { mem_size: 1 << 22, ..Default::default() };
        let f32t = run_pool_sim(core_cfg, PoolFormat::F32, &PoolConfig::LENET5, true).stats.cycles;
        let f64t = run_pool_sim(core_cfg, PoolFormat::F64, &PoolConfig::LENET5, true).stats.cycles;
        let p32t = run_pool_sim(core_cfg, PoolFormat::P32, &PoolConfig::LENET5, true).stats.cycles;
        assert!(p32t <= f32t, "posit {p32t} must not trail f32 {f32t}");
        let ratio = f64t as f64 / f32t as f64;
        assert!(ratio > 1.1, "f64/f32 = {ratio} (paper: 1.4–1.7×)");
    }
}
