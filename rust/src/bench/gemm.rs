//! GEMM workloads — the paper's §7 benchmark.
//!
//! Two execution paths share the same numeric semantics:
//! - **Simulated** ([`gemm_program`] + [`run_gemm_sim`]): the paper's
//!   Fig. 5/Fig. 6 inline-assembly kernels, generated for each variant,
//!   assembled and run on the [`crate::core`] cycle model → Table 7.
//! - **Native** ([`super::mse`]): the same arithmetic executed directly via
//!   [`crate::posit`] / host IEEE for the accuracy study → Table 6 (the
//!   simulator is bit-identical; an integration test pins that).

use crate::core::{Core, CoreConfig, HartContext, Stats};
use crate::isa::asm::{assemble, Program};
use crate::isa::PositFmt;
use crate::posit::convert::{from_f64_n, to_f64_n};
use crate::testing::Rng;
use std::collections::HashMap;
use std::sync::{Mutex, OnceLock};

/// The six arithmetic variants of Table 6/7 (plus RacEr handled in
/// [`super::racer`]), extended with the multi-width posit rows
/// (8/16/64-bit, quire and non-quire) since the Xposit `fmt` field became
/// format-generic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GemmVariant {
    /// 32-bit float with FMADD (Fig. 5).
    F32Fused,
    /// 32-bit float, mul + add.
    F32Unfused,
    /// 64-bit float with FMADD.D.
    F64Fused,
    /// 64-bit float, mul + add.
    F64Unfused,
    /// Posit32 with quire (Fig. 6).
    P32Quire,
    /// Posit32, pmul + padd.
    P32NoQuire,
    /// Posit8 with its 128-bit quire.
    P8Quire,
    /// Posit8, pmul.b + padd.b.
    P8NoQuire,
    /// Posit16 with its 256-bit quire.
    P16Quire,
    /// Posit16, pmul.h + padd.h.
    P16NoQuire,
    /// Posit64 with the 1024-bit Big-PERCIVAL quire.
    P64Quire,
    /// Posit64, pmul.d + padd.d.
    P64NoQuire,
}

impl GemmVariant {
    /// The paper's Table 7 rows (32-bit posit vs IEEE).
    pub const ALL: [GemmVariant; 6] = [
        GemmVariant::F32Fused,
        GemmVariant::F64Fused,
        GemmVariant::P32Quire,
        GemmVariant::F32Unfused,
        GemmVariant::F64Unfused,
        GemmVariant::P32NoQuire,
    ];

    /// The multi-width extension rows (everything posit except the
    /// paper's 32-bit pair, which [`Self::ALL`] already carries).
    pub const POSIT_EXT: [GemmVariant; 6] = [
        GemmVariant::P8Quire,
        GemmVariant::P8NoQuire,
        GemmVariant::P16Quire,
        GemmVariant::P16NoQuire,
        GemmVariant::P64Quire,
        GemmVariant::P64NoQuire,
    ];

    /// The posit variant for `(fmt, quire)`.
    pub fn posit(fmt: PositFmt, quire: bool) -> GemmVariant {
        match (fmt, quire) {
            (PositFmt::P8, true) => GemmVariant::P8Quire,
            (PositFmt::P8, false) => GemmVariant::P8NoQuire,
            (PositFmt::P16, true) => GemmVariant::P16Quire,
            (PositFmt::P16, false) => GemmVariant::P16NoQuire,
            (PositFmt::P32, true) => GemmVariant::P32Quire,
            (PositFmt::P32, false) => GemmVariant::P32NoQuire,
            (PositFmt::P64, true) => GemmVariant::P64Quire,
            (PositFmt::P64, false) => GemmVariant::P64NoQuire,
        }
    }

    /// Posit width of a posit variant (`None` for the IEEE ones).
    pub fn posit_fmt(&self) -> Option<PositFmt> {
        match self {
            GemmVariant::P8Quire | GemmVariant::P8NoQuire => Some(PositFmt::P8),
            GemmVariant::P16Quire | GemmVariant::P16NoQuire => Some(PositFmt::P16),
            GemmVariant::P32Quire | GemmVariant::P32NoQuire => Some(PositFmt::P32),
            GemmVariant::P64Quire | GemmVariant::P64NoQuire => Some(PositFmt::P64),
            _ => None,
        }
    }

    /// Paper row label (Table 7).
    pub fn label(&self) -> &'static str {
        match self {
            GemmVariant::F32Fused => "32-bit float",
            GemmVariant::F64Fused => "64-bit float",
            GemmVariant::P32Quire => "Posit32",
            GemmVariant::F32Unfused => "32-bit float no FMADD",
            GemmVariant::F64Unfused => "64-bit float no FMADD",
            GemmVariant::P32NoQuire => "Posit32 no quire",
            GemmVariant::P8Quire => "Posit8",
            GemmVariant::P8NoQuire => "Posit8 no quire",
            GemmVariant::P16Quire => "Posit16",
            GemmVariant::P16NoQuire => "Posit16 no quire",
            GemmVariant::P64Quire => "Posit64",
            GemmVariant::P64NoQuire => "Posit64 no quire",
        }
    }

    /// Element size in data memory.
    pub fn elem_bytes(&self) -> u64 {
        match self {
            GemmVariant::F64Fused | GemmVariant::F64Unfused => 8,
            _ => match self.posit_fmt() {
                Some(fmt) => fmt.bytes() as u64,
                None => 4,
            },
        }
    }
}

/// Assembly fragments for one posit width: (load, store, arith suffix,
/// pmv width letter).
fn posit_frags(fmt: PositFmt) -> (&'static str, &'static str, &'static str, &'static str) {
    match fmt {
        PositFmt::P8 => ("plb", "psb", "b", "b"),
        PositFmt::P16 => ("plh", "psh", "h", "h"),
        PositFmt::P32 => ("plw", "psw", "s", "w"),
        PositFmt::P64 => ("pld", "psd", "d", "d"),
    }
}

/// Generate the paper's GEMM kernel (Figs. 5/6 inner loops, with the
/// pointer strength-reduction `-O2` produces) for one variant and size.
///
/// Calling convention: `a0 = &A`, `a1 = &B`, `a2 = &C`, all row-major n×n.
pub fn gemm_program(variant: GemmVariant, n: usize) -> Program {
    let eb = variant.elem_bytes() as usize;
    let row = n * eb; // row stride in bytes
    // Per-variant fragments.
    let (init_acc, load_a, load_b, mac, store) = match variant {
        GemmVariant::F32Fused => (
            "fmv.w.x ft0, zero".to_string(),
            "flw ft1, 0(t2)".to_string(),
            "flw ft2, 0(t3)".to_string(),
            "fmadd.s ft0, ft1, ft2, ft0".to_string(),
            "fsw ft0, 0(t4)".to_string(),
        ),
        GemmVariant::F32Unfused => (
            "fmv.w.x ft0, zero".to_string(),
            "flw ft1, 0(t2)".to_string(),
            "flw ft2, 0(t3)".to_string(),
            "fmul.s ft3, ft1, ft2\n    fadd.s ft0, ft0, ft3".to_string(),
            "fsw ft0, 0(t4)".to_string(),
        ),
        GemmVariant::F64Fused => (
            "fmv.d.x ft0, zero".to_string(),
            "fld ft1, 0(t2)".to_string(),
            "fld ft2, 0(t3)".to_string(),
            "fmadd.d ft0, ft1, ft2, ft0".to_string(),
            "fsd ft0, 0(t4)".to_string(),
        ),
        GemmVariant::F64Unfused => (
            "fmv.d.x ft0, zero".to_string(),
            "fld ft1, 0(t2)".to_string(),
            "fld ft2, 0(t3)".to_string(),
            "fmul.d ft3, ft1, ft2\n    fadd.d ft0, ft0, ft3".to_string(),
            "fsd ft0, 0(t4)".to_string(),
        ),
        // The posit variants share one Fig. 6 kernel shape; the width only
        // picks the load/store opcode and the mnemonic suffix.
        _ => {
            let fmt = variant.posit_fmt().expect("posit variant");
            let (load, store, sfx, mv) = posit_frags(fmt);
            let quire = matches!(
                variant,
                GemmVariant::P8Quire
                    | GemmVariant::P16Quire
                    | GemmVariant::P32Quire
                    | GemmVariant::P64Quire
            );
            if quire {
                (
                    format!("qclr.{sfx}"),
                    format!("{load} p0, 0(t2)"),
                    format!("{load} p1, 0(t3)"),
                    format!("qmadd.{sfx} p0, p1"),
                    format!("qround.{sfx} p2\n    {store} p2, 0(t4)"),
                )
            } else {
                (
                    format!("pmv.{mv}.x p2, zero"),
                    format!("{load} p0, 0(t2)"),
                    format!("{load} p1, 0(t3)"),
                    format!("pmul.{sfx} p3, p0, p1\n    padd.{sfx} p2, p2, p3"),
                    format!("{store} p2, 0(t4)"),
                )
            }
        }
    };
    let src = format!(
        r#"
    # GEMM {variant:?} n={n} (paper Figs. 5/6 kernel shape)
    li   t5, {row}        # B row stride / A row stride (bytes)
    li   s0, {n}          # i
    mv   t0, a0           # A row pointer
    mv   t4, a2           # C pointer
loop_i:
    li   s1, {n}          # j
    mv   t6, a1           # B column base (B + 4j)
loop_j:
    {init_acc}
    mv   t2, t0           # &A[i][0]
    mv   t3, t6           # &B[0][j]
    li   s2, {n}          # k
loop_k:
    {load_a}
    {load_b}
    {mac}
    addi t2, t2, {eb}
    add  t3, t3, t5
    addi s2, s2, -1
    bnez s2, loop_k
    {store}
    addi t4, t4, {eb}
    addi t6, t6, {eb}
    addi s1, s1, -1
    bnez s1, loop_j
    add  t0, t0, t5
    addi s0, s0, -1
    bnez s0, loop_i
    ecall
"#
    );
    assemble(&src).expect("generated GEMM kernel must assemble")
}

/// [`gemm_program`] through a process-wide cache keyed by
/// `(variant, n)`: coordinator batch runs submit thousands of jobs over
/// the same few kernels, and with `Program.instrs` in shared `Arc`
/// storage a cache hit means no re-assembly and no text-segment copy —
/// every simulated core in the batch holds the same `Arc<[Instr]>`.
pub fn gemm_program_cached(variant: GemmVariant, n: usize) -> Program {
    static CACHE: OnceLock<Mutex<HashMap<(GemmVariant, usize), Program>>> = OnceLock::new();
    let cache = CACHE.get_or_init(|| Mutex::new(HashMap::new()));
    let mut map = cache.lock().expect("gemm program cache lock");
    map.entry((variant, n)).or_insert_with(|| gemm_program(variant, n)).clone()
}

/// Install the generated GEMM kernels' calling convention (`a0 = &A`,
/// `a1 = &B`, `a2 = &C`) into a hart context — the single source of the
/// argument-register assignment for the bench runners and the multi-hart
/// scheduler alike.
pub fn set_gemm_args(ctx: &mut HartContext, a: u64, b: u64, c: u64) {
    ctx.x[10] = a;
    ctx.x[11] = b;
    ctx.x[12] = c;
}

/// Install the generated dot kernel's calling convention (`a0 = &A`,
/// `a1 = &B`, `a2 = len`, `a3 = &out`); see [`set_gemm_args`].
pub fn set_dot_args(ctx: &mut HartContext, a: u64, b: u64, len: u64, out: u64) {
    ctx.x[10] = a;
    ctx.x[11] = b;
    ctx.x[12] = len;
    ctx.x[13] = out;
}

/// Memory layout used by the GEMM runs.
pub struct GemmLayout {
    pub a: u64,
    pub b: u64,
    pub c: u64,
}

pub fn layout(variant: GemmVariant, n: usize) -> GemmLayout {
    let eb = variant.elem_bytes();
    let sz = (n * n) as u64 * eb;
    let align = |x: u64| (x + 0xFFF) & !0xFFF;
    let a = 0x1_0000;
    let b = align(a + sz);
    let c = align(b + sz);
    GemmLayout { a, b, c }
}

/// Fill simulator memory with the input matrices converted to the variant's
/// format, the same way the paper feeds SoftPosit-converted doubles.
pub fn load_inputs(core: &mut Core, variant: GemmVariant, n: usize, af: &[f64], bf: &[f64]) {
    let lo = layout(variant, n);
    match variant {
        GemmVariant::F64Fused | GemmVariant::F64Unfused => {
            core.mem.write_f64_slice(lo.a, af);
            core.mem.write_f64_slice(lo.b, bf);
        }
        GemmVariant::F32Fused | GemmVariant::F32Unfused => {
            let a32: Vec<f32> = af.iter().map(|v| *v as f32).collect();
            let b32: Vec<f32> = bf.iter().map(|v| *v as f32).collect();
            core.mem.write_f32_slice(lo.a, &a32);
            core.mem.write_f32_slice(lo.b, &b32);
        }
        _ => {
            let fmt = variant.posit_fmt().expect("posit variant");
            let (w, eb) = (fmt.width(), fmt.bytes());
            let ap: Vec<u64> = af.iter().map(|v| from_f64_n(w, *v)).collect();
            let bp: Vec<u64> = bf.iter().map(|v| from_f64_n(w, *v)).collect();
            core.mem.write_posit_slice(lo.a, eb, &ap);
            core.mem.write_posit_slice(lo.b, eb, &bp);
        }
    }
}

/// Read back C as f64 (exact for every format except Posit64, whose
/// ~59-bit significand exceeds f64 — use [`run_gemm_sim_bits`] for
/// bit-level access at any width).
pub fn read_result(core: &Core, variant: GemmVariant, n: usize) -> Vec<f64> {
    let lo = layout(variant, n);
    match variant {
        GemmVariant::F64Fused | GemmVariant::F64Unfused => core.mem.read_f64_slice(lo.c, n * n),
        GemmVariant::F32Fused | GemmVariant::F32Unfused => {
            core.mem.read_f32_slice(lo.c, n * n).iter().map(|v| *v as f64).collect()
        }
        _ => {
            let fmt = variant.posit_fmt().expect("posit variant");
            core.mem
                .read_posit_slice(lo.c, fmt.bytes(), n * n)
                .iter()
                .map(|v| to_f64_n(fmt.width(), *v))
                .collect()
        }
    }
}

/// Outcome of a simulated GEMM.
pub struct GemmRun {
    pub stats: Stats,
    pub result: Vec<f64>,
    pub seconds: f64,
}

/// Assemble, load, warm (one full run, discarded — the paper avoids cold
/// misses), then measure one timed run on the core model.
pub fn run_gemm_sim(
    cfg: CoreConfig,
    variant: GemmVariant,
    n: usize,
    af: &[f64],
    bf: &[f64],
    warm: bool,
) -> GemmRun {
    let prog = gemm_program_cached(variant, n);
    let mut core = Core::new(cfg);
    core.load_program(&prog);
    load_inputs(&mut core, variant, n, af, bf);
    let lo = layout(variant, n);
    let set_args = |core: &mut Core| set_gemm_args(&mut core.ctx, lo.a, lo.b, lo.c);
    if warm {
        set_args(&mut core);
        core.run();
        core.reset_timing();
    }
    set_args(&mut core);
    let stats = core.run();
    let seconds = stats.seconds(&core.cfg);
    GemmRun { stats, result: read_result(&core, variant, n), seconds }
}

/// Outcome of a simulated posit workload run on raw bit patterns.
pub struct SimBitsRun {
    /// Result bit patterns (`u64`, lossless for every width).
    pub bits: Vec<u64>,
    pub stats: Stats,
    /// Simulated target seconds at the configured clock.
    pub seconds: f64,
}

/// Simulated GEMM on raw posit bit patterns at any width — the
/// coordinator's `Backend::Sim` route for format-tagged jobs. Unlike
/// [`run_gemm_sim`] (which converts from f64 masters) this writes and
/// reads the patterns verbatim, so it is lossless even for Posit64.
pub fn run_gemm_sim_bits(
    cfg: CoreConfig,
    fmt: PositFmt,
    n: usize,
    a: &[u64],
    b: &[u64],
    quire: bool,
    warm: bool,
) -> SimBitsRun {
    assert_eq!(a.len(), n * n, "A must be n×n");
    assert_eq!(b.len(), n * n, "B must be n×n");
    let variant = GemmVariant::posit(fmt, quire);
    let prog = gemm_program_cached(variant, n);
    let mut core = Core::new(cfg);
    core.load_program(&prog);
    let lo = layout(variant, n);
    let eb = fmt.bytes();
    core.mem.write_posit_slice(lo.a, eb, a);
    core.mem.write_posit_slice(lo.b, eb, b);
    let set_args = |core: &mut Core| set_gemm_args(&mut core.ctx, lo.a, lo.b, lo.c);
    if warm {
        set_args(&mut core);
        core.run();
        core.reset_timing();
    }
    set_args(&mut core);
    let stats = core.run();
    let seconds = stats.seconds(&core.cfg);
    SimBitsRun { bits: core.mem.read_posit_slice(lo.c, eb, n * n), stats, seconds }
}

/// Generate the quire dot-product kernel at one posit width (the Fig. 6
/// inner loop on its own). Calling convention: `a0 = &A`, `a1 = &B`,
/// `a2 = len`, `a3 = &out`.
pub fn dot_program(fmt: PositFmt, len: usize) -> Program {
    let (load, store, sfx, _) = posit_frags(fmt);
    let eb = fmt.bytes();
    let src = format!(
        r#"
    # quire dot product {fmt:?} len={len}
    qclr.{sfx}
    beqz a2, done
loop:
    {load} p0, 0(a0)
    {load} p1, 0(a1)
    qmadd.{sfx} p0, p1
    addi a0, a0, {eb}
    addi a1, a1, {eb}
    addi a2, a2, -1
    bnez a2, loop
done:
    qround.{sfx} p2
    {store} p2, 0(a3)
    ecall
"#
    );
    assemble(&src).expect("generated dot kernel must assemble")
}

/// Generate the *partial* quire dot-product kernel: the same Fig. 6 inner
/// loop as [`dot_program`], but instead of rounding it spills the raw
/// quire image with `qsq` (cycle-accounted like any other quire
/// store). Calling convention: `a0 = &A`, `a1 = &B`, `a2 = len`,
/// `a3 = &out` (8-byte aligned, `fmt.quire_bytes()` long). Shard-
/// decomposed jobs run this per shard and the host merges the spill
/// images via `Quire::merge` — bit-identical to one serial dot.
pub fn dot_partial_program(fmt: PositFmt, len: usize) -> Program {
    let (load, _, sfx, _) = posit_frags(fmt);
    let eb = fmt.bytes();
    let src = format!(
        r#"
    # partial quire dot product {fmt:?} len={len} (spills the quire, no round)
    qclr.{sfx}
    beqz a2, done
loop:
    {load} p0, 0(a0)
    {load} p1, 0(a1)
    qmadd.{sfx} p0, p1
    addi a0, a0, {eb}
    addi a1, a1, {eb}
    addi a2, a2, -1
    bnez a2, loop
done:
    qsq.{sfx} (a3)
    ecall
"#
    );
    assemble(&src).expect("generated partial dot kernel must assemble")
}

/// Simulated quire dot product on raw posit bit patterns at any width.
pub fn run_dot_sim_bits(cfg: CoreConfig, fmt: PositFmt, a: &[u64], b: &[u64]) -> SimBitsRun {
    assert_eq!(a.len(), b.len());
    let prog = dot_program(fmt, a.len());
    let mut core = Core::new(cfg);
    core.load_program(&prog);
    let eb = fmt.bytes();
    let base_a = 0x1_0000u64;
    let base_b = base_a + ((a.len() * eb + 0xFFF) & !0xFFF) as u64;
    let out = base_b + ((b.len() * eb + 0xFFF) & !0xFFF) as u64;
    core.mem.write_posit_slice(base_a, eb, a);
    core.mem.write_posit_slice(base_b, eb, b);
    set_dot_args(&mut core.ctx, base_a, base_b, a.len() as u64, out);
    let stats = core.run();
    let seconds = stats.seconds(&core.cfg);
    SimBitsRun { bits: core.mem.read_posit_slice(out, eb, 1), stats, seconds }
}

/// Simulated *partial* quire dot product: runs [`dot_partial_program`] and
/// returns the spilled quire image as little-endian `u64` limbs (the
/// shard-decomposed jobs' partial-result representation). The `qsq` spill
/// is cycle-accounted in `stats` like any context-switch spill.
pub fn run_dot_partial_sim_bits(cfg: CoreConfig, fmt: PositFmt, a: &[u64], b: &[u64]) -> SimBitsRun {
    assert_eq!(a.len(), b.len());
    let prog = dot_partial_program(fmt, a.len());
    let mut core = Core::new(cfg);
    core.load_program(&prog);
    let eb = fmt.bytes();
    let base_a = 0x1_0000u64;
    let base_b = base_a + ((a.len() * eb + 0xFFF) & !0xFFF) as u64;
    let out = base_b + ((b.len() * eb + 0xFFF) & !0xFFF) as u64; // page- (so 8-byte-) aligned
    core.mem.write_posit_slice(base_a, eb, a);
    core.mem.write_posit_slice(base_b, eb, b);
    set_dot_args(&mut core.ctx, base_a, base_b, a.len() as u64, out);
    let stats = core.run();
    let seconds = stats.seconds(&core.cfg);
    let bits = core
        .mem
        .read_bytes(out, fmt.quire_bytes())
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().expect("8-byte chunk")))
        .collect();
    SimBitsRun { bits, stats, seconds }
}

/// Deterministic uniform matrix in `[-10^i, 10^i]` (paper §7.1's input
/// generator), as f64 "master" values that each variant converts from.
pub fn gen_matrix(rng: &mut Rng, n: usize, exp10: i32) -> Vec<f64> {
    let hi = 10f64.powi(exp10);
    (0..n * n).map(|_| rng.range_f64(-hi, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::mse::{gemm_native, NativeKind};

    #[test]
    fn gemm_programs_are_cached_for_batch_runs() {
        // Two requests for the same kernel must share one text segment
        // (the Arc-backed batch-run invariant), and distinct kernels
        // must not collide.
        let p1 = gemm_program_cached(GemmVariant::P32Quire, 5);
        let p2 = gemm_program_cached(GemmVariant::P32Quire, 5);
        assert!(std::sync::Arc::ptr_eq(&p1.instrs, &p2.instrs));
        let p3 = gemm_program_cached(GemmVariant::P32NoQuire, 5);
        assert!(!std::sync::Arc::ptr_eq(&p1.instrs, &p3.instrs));
        assert_eq!(p1.words, gemm_program(GemmVariant::P32Quire, 5).words);
    }

    #[test]
    fn all_variants_assemble() {
        for v in GemmVariant::ALL.into_iter().chain(GemmVariant::POSIT_EXT) {
            let p = gemm_program(v, 8);
            assert!(p.words.len() > 15, "{v:?}");
        }
        for fmt in PositFmt::ALL {
            let p = dot_program(fmt, 8);
            assert!(p.words.len() > 8, "{fmt:?}");
        }
    }

    #[test]
    fn sim_bits_matches_generic_kernels_every_width() {
        // The simulated multi-width kernels and the native generic kernel
        // drivers are two engines over the same arithmetic: bit-identical.
        use crate::posit::{P16, P32, P64, P8};
        fn check<F: crate::kernels::gemm::KernelFormat>(fmt: PositFmt, seed: u64) {
            use crate::kernels::gemm::{gemm_noquire, gemm_quire};
            use crate::posit::PositBits;
            let mut rng = Rng::new(seed);
            let n = 5;
            let a: Vec<u64> =
                (0..n * n).map(|_| from_f64_n(F::N, rng.range_f64(-2.0, 2.0))).collect();
            let b: Vec<u64> =
                (0..n * n).map(|_| from_f64_n(F::N, rng.range_f64(-2.0, 2.0))).collect();
            let af: Vec<F::Bits> = a.iter().map(|&x| F::Bits::from_u64(x)).collect();
            let bf: Vec<F::Bits> = b.iter().map(|&x| F::Bits::from_u64(x)).collect();
            let cfg = CoreConfig { mem_size: 1 << 22, ..Default::default() };
            for quire in [true, false] {
                let sim = run_gemm_sim_bits(cfg, fmt, n, &a, &b, quire, false);
                assert!(sim.seconds > 0.0);
                let native = if quire {
                    gemm_quire::<F>(n, &af, &bf)
                } else {
                    gemm_noquire::<F>(n, &af, &bf)
                };
                let native: Vec<u64> = native.into_iter().map(|x| x.to_u64()).collect();
                assert_eq!(sim.bits, native, "{fmt:?} quire={quire}");
            }
        }
        check::<P8>(PositFmt::P8, 81);
        check::<P16>(PositFmt::P16, 161);
        check::<P32>(PositFmt::P32, 321);
        check::<P64>(PositFmt::P64, 641);
    }

    #[test]
    fn sim_dot_matches_native_every_width() {
        use crate::kernels::gemm::dot_quire;
        use crate::posit::{PositBits, P16, P64};
        let cfg = CoreConfig { mem_size: 1 << 22, ..Default::default() };
        // Empty dot rounds the cleared quire: exactly zero at any width.
        assert_eq!(run_dot_sim_bits(cfg, PositFmt::P16, &[], &[]).bits, vec![0]);
        let mut rng = Rng::new(0xD07);
        let a16: Vec<u64> = (0..33).map(|_| from_f64_n(16, rng.range_f64(-4.0, 4.0))).collect();
        let b16: Vec<u64> = (0..33).map(|_| from_f64_n(16, rng.range_f64(-4.0, 4.0))).collect();
        let a16n: Vec<u32> = a16.iter().map(|&x| x as u32).collect();
        let b16n: Vec<u32> = b16.iter().map(|&x| x as u32).collect();
        assert_eq!(
            run_dot_sim_bits(cfg, PositFmt::P16, &a16, &b16).bits,
            vec![dot_quire::<P16>(&a16n, &b16n) as u64]
        );
        let a64: Vec<u64> = (0..17).map(|_| from_f64_n(64, rng.range_f64(-4.0, 4.0))).collect();
        let b64: Vec<u64> = (0..17).map(|_| from_f64_n(64, rng.range_f64(-4.0, 4.0))).collect();
        assert_eq!(
            run_dot_sim_bits(cfg, PositFmt::P64, &a64, &b64).bits,
            vec![dot_quire::<P64>(&a64, &b64).to_u64()]
        );
    }

    #[test]
    fn simulated_matches_native_bitwise() {
        // The simulated kernel and the native library path must agree
        // *bit for bit* for every variant (same arithmetic, two engines).
        let n = 6;
        let mut rng = Rng::new(2024);
        let a = gen_matrix(&mut rng, n, 0);
        let b = gen_matrix(&mut rng, n, 0);
        let cfg = CoreConfig { mem_size: 1 << 22, ..Default::default() };
        for v in GemmVariant::ALL {
            let sim = run_gemm_sim(cfg, v, n, &a, &b, false);
            let native = gemm_native(kind_of(v), n, &a, &b);
            assert_eq!(sim.result, native, "variant {v:?}");
        }
    }

    fn kind_of(v: GemmVariant) -> NativeKind {
        match v {
            GemmVariant::F32Fused => NativeKind::F32Fused,
            GemmVariant::F32Unfused => NativeKind::F32Unfused,
            GemmVariant::F64Fused => NativeKind::F64Fused,
            GemmVariant::F64Unfused => NativeKind::F64Unfused,
            GemmVariant::P32Quire => NativeKind::P32Quire,
            GemmVariant::P32NoQuire => NativeKind::P32NoQuire,
            _ => unreachable!("no Table-6 native kind for {v:?}"),
        }
    }

    #[test]
    fn fast_engines_match_oracle_all_variants() {
        // Every Table 7 variant, all three engines: Stats and result
        // bits must be identical (the superblock and binary-translation
        // acceptance pin at GEMM scale).
        use crate::core::Engine;
        let n = 6;
        let mut rng = Rng::new(0xB10C);
        let a = gen_matrix(&mut rng, n, 0);
        let b = gen_matrix(&mut rng, n, 0);
        for v in GemmVariant::ALL.into_iter().chain(GemmVariant::POSIT_EXT) {
            let or = run_gemm_sim(
                CoreConfig { mem_size: 1 << 22, engine: Engine::Oracle, ..Default::default() },
                v,
                n,
                &a,
                &b,
                true,
            );
            for engine in [Engine::Superblock, Engine::Translated] {
                let fast = run_gemm_sim(
                    CoreConfig { mem_size: 1 << 22, engine, ..Default::default() },
                    v,
                    n,
                    &a,
                    &b,
                    true,
                );
                assert_eq!(fast.stats, or.stats, "{v:?} ({engine:?})");
                assert_eq!(fast.result, or.result, "{v:?} ({engine:?})");
                assert_eq!(fast.seconds, or.seconds, "{v:?} ({engine:?})");
            }
        }
    }

    #[test]
    fn quire_gemm_simulated_identity() {
        // C = A·I must reproduce A exactly (quire path, exact rounding).
        let n = 4;
        let a: Vec<f64> = (0..n * n).map(|i| (i as f64 - 7.0) * 0.375).collect();
        let mut b = vec![0.0; n * n];
        for i in 0..n {
            b[i * n + i] = 1.0;
        }
        let cfg = CoreConfig { mem_size: 1 << 22, ..Default::default() };
        let run = run_gemm_sim(cfg, GemmVariant::P32Quire, n, &a, &b, false);
        for (got, want) in run.result.iter().zip(&a) {
            assert_eq!(got, want);
        }
    }

    #[test]
    fn timing_scales_roughly_cubically() {
        let mut rng = Rng::new(7);
        let a = gen_matrix(&mut rng, 16, 0);
        let b = gen_matrix(&mut rng, 16, 0);
        let cfg = CoreConfig { mem_size: 1 << 22, ..Default::default() };
        let t8 = run_gemm_sim(cfg, GemmVariant::P32Quire, 8, &a[..64], &b[..64], true).stats.cycles;
        let t16 = run_gemm_sim(cfg, GemmVariant::P32Quire, 16, &a, &b, true).stats.cycles;
        let ratio = t16 as f64 / t8 as f64;
        assert!((4.0..16.0).contains(&ratio), "ratio {ratio}");
    }
}
