//! GEMM workloads — the paper's §7 benchmark.
//!
//! Two execution paths share the same numeric semantics:
//! - **Simulated** ([`gemm_program`] + [`run_gemm_sim`]): the paper's
//!   Fig. 5/Fig. 6 inline-assembly kernels, generated for each variant,
//!   assembled and run on the [`crate::core`] cycle model → Table 7.
//! - **Native** ([`super::mse`]): the same arithmetic executed directly via
//!   [`crate::posit`] / host IEEE for the accuracy study → Table 6 (the
//!   simulator is bit-identical; an integration test pins that).

use crate::core::{Core, CoreConfig, Stats};
use crate::isa::asm::{assemble, Program};
use crate::posit::Posit32;
use crate::testing::Rng;

/// The six arithmetic variants of Table 6/7 (plus RacEr handled in
/// [`super::racer`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GemmVariant {
    /// 32-bit float with FMADD (Fig. 5).
    F32Fused,
    /// 32-bit float, mul + add.
    F32Unfused,
    /// 64-bit float with FMADD.D.
    F64Fused,
    /// 64-bit float, mul + add.
    F64Unfused,
    /// Posit32 with quire (Fig. 6).
    P32Quire,
    /// Posit32, pmul + padd.
    P32NoQuire,
}

impl GemmVariant {
    pub const ALL: [GemmVariant; 6] = [
        GemmVariant::F32Fused,
        GemmVariant::F64Fused,
        GemmVariant::P32Quire,
        GemmVariant::F32Unfused,
        GemmVariant::F64Unfused,
        GemmVariant::P32NoQuire,
    ];

    /// Paper row label (Table 7).
    pub fn label(&self) -> &'static str {
        match self {
            GemmVariant::F32Fused => "32-bit float",
            GemmVariant::F64Fused => "64-bit float",
            GemmVariant::P32Quire => "Posit32",
            GemmVariant::F32Unfused => "32-bit float no FMADD",
            GemmVariant::F64Unfused => "64-bit float no FMADD",
            GemmVariant::P32NoQuire => "Posit32 no quire",
        }
    }

    /// Element size in data memory.
    pub fn elem_bytes(&self) -> u64 {
        match self {
            GemmVariant::F64Fused | GemmVariant::F64Unfused => 8,
            _ => 4,
        }
    }
}

/// Generate the paper's GEMM kernel (Figs. 5/6 inner loops, with the
/// pointer strength-reduction `-O2` produces) for one variant and size.
///
/// Calling convention: `a0 = &A`, `a1 = &B`, `a2 = &C`, all row-major n×n.
pub fn gemm_program(variant: GemmVariant, n: usize) -> Program {
    let eb = variant.elem_bytes() as usize;
    let row = n * eb; // row stride in bytes
    // Per-variant fragments.
    let (init_acc, load_a, load_b, mac, store) = match variant {
        GemmVariant::F32Fused => (
            "fmv.w.x ft0, zero",
            "flw ft1, 0(t2)",
            "flw ft2, 0(t3)",
            "fmadd.s ft0, ft1, ft2, ft0".to_string(),
            "fsw ft0, 0(t4)",
        ),
        GemmVariant::F32Unfused => (
            "fmv.w.x ft0, zero",
            "flw ft1, 0(t2)",
            "flw ft2, 0(t3)",
            "fmul.s ft3, ft1, ft2\n    fadd.s ft0, ft0, ft3".to_string(),
            "fsw ft0, 0(t4)",
        ),
        GemmVariant::F64Fused => (
            "fmv.d.x ft0, zero",
            "fld ft1, 0(t2)",
            "fld ft2, 0(t3)",
            "fmadd.d ft0, ft1, ft2, ft0".to_string(),
            "fsd ft0, 0(t4)",
        ),
        GemmVariant::F64Unfused => (
            "fmv.d.x ft0, zero",
            "fld ft1, 0(t2)",
            "fld ft2, 0(t3)",
            "fmul.d ft3, ft1, ft2\n    fadd.d ft0, ft0, ft3".to_string(),
            "fsd ft0, 0(t4)",
        ),
        GemmVariant::P32Quire => (
            "qclr.s",
            "plw p0, 0(t2)",
            "plw p1, 0(t3)",
            "qmadd.s p0, p1".to_string(),
            "qround.s p2\n    psw p2, 0(t4)",
        ),
        GemmVariant::P32NoQuire => (
            "pmv.w.x p2, zero",
            "plw p0, 0(t2)",
            "plw p1, 0(t3)",
            "pmul.s p3, p0, p1\n    padd.s p2, p2, p3".to_string(),
            "psw p2, 0(t4)",
        ),
    };
    let src = format!(
        r#"
    # GEMM {variant:?} n={n} (paper Figs. 5/6 kernel shape)
    li   t5, {row}        # B row stride / A row stride (bytes)
    li   s0, {n}          # i
    mv   t0, a0           # A row pointer
    mv   t4, a2           # C pointer
loop_i:
    li   s1, {n}          # j
    mv   t6, a1           # B column base (B + 4j)
loop_j:
    {init_acc}
    mv   t2, t0           # &A[i][0]
    mv   t3, t6           # &B[0][j]
    li   s2, {n}          # k
loop_k:
    {load_a}
    {load_b}
    {mac}
    addi t2, t2, {eb}
    add  t3, t3, t5
    addi s2, s2, -1
    bnez s2, loop_k
    {store}
    addi t4, t4, {eb}
    addi t6, t6, {eb}
    addi s1, s1, -1
    bnez s1, loop_j
    add  t0, t0, t5
    addi s0, s0, -1
    bnez s0, loop_i
    ecall
"#
    );
    assemble(&src).expect("generated GEMM kernel must assemble")
}

/// Memory layout used by the GEMM runs.
pub struct GemmLayout {
    pub a: u64,
    pub b: u64,
    pub c: u64,
}

pub fn layout(variant: GemmVariant, n: usize) -> GemmLayout {
    let eb = variant.elem_bytes();
    let sz = (n * n) as u64 * eb;
    let align = |x: u64| (x + 0xFFF) & !0xFFF;
    let a = 0x1_0000;
    let b = align(a + sz);
    let c = align(b + sz);
    GemmLayout { a, b, c }
}

/// Fill simulator memory with the input matrices converted to the variant's
/// format, the same way the paper feeds SoftPosit-converted doubles.
pub fn load_inputs(core: &mut Core, variant: GemmVariant, n: usize, af: &[f64], bf: &[f64]) {
    let lo = layout(variant, n);
    match variant {
        GemmVariant::F64Fused | GemmVariant::F64Unfused => {
            core.mem.write_f64_slice(lo.a, af);
            core.mem.write_f64_slice(lo.b, bf);
        }
        GemmVariant::F32Fused | GemmVariant::F32Unfused => {
            let a32: Vec<f32> = af.iter().map(|v| *v as f32).collect();
            let b32: Vec<f32> = bf.iter().map(|v| *v as f32).collect();
            core.mem.write_f32_slice(lo.a, &a32);
            core.mem.write_f32_slice(lo.b, &b32);
        }
        GemmVariant::P32Quire | GemmVariant::P32NoQuire => {
            let ap: Vec<u32> = af.iter().map(|v| Posit32::from_f64(*v).bits()).collect();
            let bp: Vec<u32> = bf.iter().map(|v| Posit32::from_f64(*v).bits()).collect();
            core.mem.write_u32_slice(lo.a, &ap);
            core.mem.write_u32_slice(lo.b, &bp);
        }
    }
}

/// Read back C as f64 (exact for all formats).
pub fn read_result(core: &Core, variant: GemmVariant, n: usize) -> Vec<f64> {
    let lo = layout(variant, n);
    match variant {
        GemmVariant::F64Fused | GemmVariant::F64Unfused => core.mem.read_f64_slice(lo.c, n * n),
        GemmVariant::F32Fused | GemmVariant::F32Unfused => {
            core.mem.read_f32_slice(lo.c, n * n).iter().map(|v| *v as f64).collect()
        }
        GemmVariant::P32Quire | GemmVariant::P32NoQuire => core
            .mem
            .read_u32_slice(lo.c, n * n)
            .iter()
            .map(|v| Posit32(*v).to_f64())
            .collect(),
    }
}

/// Outcome of a simulated GEMM.
pub struct GemmRun {
    pub stats: Stats,
    pub result: Vec<f64>,
    pub seconds: f64,
}

/// Assemble, load, warm (one full run, discarded — the paper avoids cold
/// misses), then measure one timed run on the core model.
pub fn run_gemm_sim(
    cfg: CoreConfig,
    variant: GemmVariant,
    n: usize,
    af: &[f64],
    bf: &[f64],
    warm: bool,
) -> GemmRun {
    let prog = gemm_program(variant, n);
    let mut core = Core::new(cfg);
    core.load_program(&prog);
    load_inputs(&mut core, variant, n, af, bf);
    let lo = layout(variant, n);
    let set_args = |core: &mut Core| {
        core.x[10] = lo.a;
        core.x[11] = lo.b;
        core.x[12] = lo.c;
    };
    if warm {
        set_args(&mut core);
        core.run();
        core.reset_timing();
    }
    set_args(&mut core);
    let stats = core.run();
    let seconds = stats.seconds(&core.cfg);
    GemmRun { stats, result: read_result(&core, variant, n), seconds }
}

/// Deterministic uniform matrix in `[-10^i, 10^i]` (paper §7.1's input
/// generator), as f64 "master" values that each variant converts from.
pub fn gen_matrix(rng: &mut Rng, n: usize, exp10: i32) -> Vec<f64> {
    let hi = 10f64.powi(exp10);
    (0..n * n).map(|_| rng.range_f64(-hi, hi)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::mse::{gemm_native, NativeKind};

    #[test]
    fn all_variants_assemble() {
        for v in GemmVariant::ALL {
            let p = gemm_program(v, 8);
            assert!(p.words.len() > 15);
        }
    }

    #[test]
    fn simulated_matches_native_bitwise() {
        // The simulated kernel and the native library path must agree
        // *bit for bit* for every variant (same arithmetic, two engines).
        let n = 6;
        let mut rng = Rng::new(2024);
        let a = gen_matrix(&mut rng, n, 0);
        let b = gen_matrix(&mut rng, n, 0);
        let cfg = CoreConfig { mem_size: 1 << 22, ..Default::default() };
        for v in GemmVariant::ALL {
            let sim = run_gemm_sim(cfg, v, n, &a, &b, false);
            let native = gemm_native(kind_of(v), n, &a, &b);
            assert_eq!(sim.result, native, "variant {v:?}");
        }
    }

    fn kind_of(v: GemmVariant) -> NativeKind {
        match v {
            GemmVariant::F32Fused => NativeKind::F32Fused,
            GemmVariant::F32Unfused => NativeKind::F32Unfused,
            GemmVariant::F64Fused => NativeKind::F64Fused,
            GemmVariant::F64Unfused => NativeKind::F64Unfused,
            GemmVariant::P32Quire => NativeKind::P32Quire,
            GemmVariant::P32NoQuire => NativeKind::P32NoQuire,
        }
    }

    #[test]
    fn quire_gemm_simulated_identity() {
        // C = A·I must reproduce A exactly (quire path, exact rounding).
        let n = 4;
        let a: Vec<f64> = (0..n * n).map(|i| (i as f64 - 7.0) * 0.375).collect();
        let mut b = vec![0.0; n * n];
        for i in 0..n {
            b[i * n + i] = 1.0;
        }
        let cfg = CoreConfig { mem_size: 1 << 22, ..Default::default() };
        let run = run_gemm_sim(cfg, GemmVariant::P32Quire, n, &a, &b, false);
        for (got, want) in run.result.iter().zip(&a) {
            assert_eq!(got, want);
        }
    }

    #[test]
    fn timing_scales_roughly_cubically() {
        let mut rng = Rng::new(7);
        let a = gen_matrix(&mut rng, 16, 0);
        let b = gen_matrix(&mut rng, 16, 0);
        let cfg = CoreConfig { mem_size: 1 << 22, ..Default::default() };
        let t8 = run_gemm_sim(cfg, GemmVariant::P32Quire, 8, &a[..64], &b[..64], true).stats.cycles;
        let t16 = run_gemm_sim(cfg, GemmVariant::P32Quire, 16, &a, &b, true).stats.cycles;
        let ratio = t16 as f64 / t8 as f64;
        assert!((4.0..16.0).contains(&ratio), "ratio {ratio}");
    }
}
