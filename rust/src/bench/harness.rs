//! Minimal wall-clock benchmark harness (criterion is not in the offline
//! crate set). Deterministic workloads + median-of-N timing with warm-up,
//! which is also how the paper measures: "avoiding cold misses and
//! averaging over 10 executions" (§7.2).

use std::time::Instant;

/// Result of a timed run.
#[derive(Debug, Clone, Copy)]
pub struct Report {
    pub iters: u32,
    pub mean_s: f64,
    /// Median of the measured samples (robust to scheduler outliers).
    pub median_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub std_s: f64,
}

impl Report {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }

    /// Nanoseconds per operation for a run whose body performed `n` ops
    /// per iteration (mean-based; the bench binaries share this instead
    /// of each re-deriving the conversion).
    pub fn ns_per_op(&self, n: usize) -> f64 {
        self.mean_s / n.max(1) as f64 * 1e9
    }
}

impl std::fmt::Display for Report {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:.3} ms  (median {:.3}, min {:.3}, max {:.3}, σ {:.3}, n={})",
            self.mean_s * 1e3,
            self.median_s * 1e3,
            self.min_s * 1e3,
            self.max_s * 1e3,
            self.std_s * 1e3,
            self.iters
        )
    }
}

/// Time `f` with `warmup` discarded runs and `iters` measured runs.
pub fn time<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Report {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    let mut sorted = samples.clone();
    sorted.sort_by(|a, b| a.total_cmp(b));
    let median = if sorted.len() % 2 == 1 {
        sorted[sorted.len() / 2]
    } else {
        (sorted[sorted.len() / 2 - 1] + sorted[sorted.len() / 2]) / 2.0
    };
    Report {
        iters: iters.max(1),
        mean_s: mean,
        median_s: median,
        min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().copied().fold(0.0, f64::max),
        std_s: var.sqrt(),
    }
}

/// Named benchmark entry for `cargo bench` binaries: prints a criterion-ish
/// line `name ... mean X ms`.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, f: F) -> Report {
    let r = time(warmup, iters, f);
    println!("{name:<52} {r}");
    r
}

/// Pretty-print a table: header row + aligned columns.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Write rows as CSV (for EXPERIMENTS.md provenance and plotting).
pub fn write_csv(
    path: &str,
    header: &[&str],
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// One machine-readable row of a `BENCH_*.json` perf-tracking file.
/// `mean_s` is seconds and `ns_per_op` nanoseconds for every row;
/// `speedup_x` optionally annotates a row with a unitless ratio against
/// its baseline (kept out of the timing fields so aggregators can treat
/// them uniformly).
#[derive(Debug, Clone)]
pub struct JsonRow {
    pub bench: String,
    pub mean_s: f64,
    pub ns_per_op: f64,
    pub speedup_x: Option<f64>,
}

impl JsonRow {
    /// Build a row from a [`Report`] for a body that performed `n` ops
    /// per iteration.
    pub fn from_report(bench: impl Into<String>, r: &Report, n: usize) -> Self {
        Self { bench: bench.into(), mean_s: r.mean_s, ns_per_op: r.ns_per_op(n), speedup_x: None }
    }

    fn to_json(&self) -> String {
        // The bench names are ASCII identifiers/labels; escape the two
        // characters that could break the literal anyway.
        let name = self.bench.replace('\\', "\\\\").replace('"', "\\\"");
        let extra = self
            .speedup_x
            .map(|s| format!(", \"speedup_x\": {s:.3}"))
            .unwrap_or_default();
        format!(
            "{{\"bench\": \"{}\", \"mean_s\": {:e}, \"ns_per_op\": {:.3}{}}}",
            name, self.mean_s, self.ns_per_op, extra
        )
    }
}

/// Merge `rows` into a JSON benchmark file (array of objects, one per
/// line). Rows already in the file whose `bench` name is not being
/// rewritten are preserved, so several bench binaries can contribute to
/// the same tracking file (e.g. `BENCH_posit_kernels.json`). The
/// existing file is read with the in-tree JSON parser, so any valid
/// formatting survives a merge — but rows are normalised to the
/// `{bench, mean_s, ns_per_op[, speedup_x]}` schema: rows missing the
/// required fields, and any unknown extra fields, are dropped with a
/// warning on stderr.
pub fn write_bench_json(path: &str, rows: &[JsonRow]) -> std::io::Result<()> {
    use crate::coordinator::json::{self, Value};
    use std::io::Write;
    let as_f64 = |v: &Value| match v {
        Value::Num(x) => Some(*x),
        Value::Int(i) => Some(*i as f64),
        _ => None,
    };
    let mut merged: Vec<JsonRow> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        match json::parse(&text) {
            Ok(Value::Arr(items)) => {
                for it in &items {
                    let bench = match it.get("bench") {
                        Some(Value::Str(s)) => s.clone(),
                        _ => {
                            eprintln!("warning: {path}: dropping row without a `bench` name");
                            continue;
                        }
                    };
                    let mean_s = it.get("mean_s").and_then(as_f64);
                    let ns_per_op = it.get("ns_per_op").and_then(as_f64);
                    let (Some(mean_s), Some(ns_per_op)) = (mean_s, ns_per_op) else {
                        eprintln!(
                            "warning: {path}: dropping row `{bench}` missing mean_s/ns_per_op"
                        );
                        continue;
                    };
                    if !rows.iter().any(|r| r.bench == bench) {
                        let speedup_x = it.get("speedup_x").and_then(as_f64);
                        merged.push(JsonRow { bench, mean_s, ns_per_op, speedup_x });
                    }
                }
            }
            Ok(_) | Err(_) => {
                eprintln!("warning: {path} is not a JSON row array; rewriting from scratch");
            }
        }
    }
    merged.extend(rows.iter().cloned());
    if let Some(dir) = std::path::Path::new(path).parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let lines: Vec<String> = merged.iter().map(|r| r.to_json()).collect();
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "[")?;
    writeln!(f, "{}", lines.join(",\n"))?;
    writeln!(f, "]")?;
    Ok(())
}

/// Engineering formatting for seconds, paper-style ("0.978 ms", "13.9 s").
pub fn fmt_time(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.3} s")
    } else if seconds >= 1e-4 {
        // The paper prints sub-millisecond GEMM times in ms ("0.978 ms").
        format!("{:.3} ms", seconds * 1e3)
    } else {
        format!("{:.3} µs", seconds * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_reports_sane_stats() {
        let mut x = 0u64;
        let r = time(1, 5, || {
            for i in 0..10_000u64 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(r.iters, 5);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s);
        assert!(r.min_s <= r.median_s && r.median_s <= r.max_s);
        assert!(std::hint::black_box(x) != 1);
        // ns_per_op is the shared mean→per-op conversion.
        let per = r.ns_per_op(10_000);
        assert!((per - r.mean_s / 10_000.0 * 1e9).abs() < 1e-9);
    }

    #[test]
    fn bench_json_merges_by_name() {
        let dir = std::env::temp_dir().join("percival_bench_json_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("BENCH_test.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        write_bench_json(
            path,
            &[
                JsonRow { bench: "a".into(), mean_s: 1.0, ns_per_op: 10.0, speedup_x: Some(2.5) },
                JsonRow { bench: "b".into(), mean_s: 2.0, ns_per_op: 20.0, speedup_x: None },
            ],
        )
        .unwrap();
        // Rewriting `b` keeps `a` (with its annotation) and replaces `b`.
        write_bench_json(
            path,
            &[JsonRow { bench: "b".into(), mean_s: 3.0, ns_per_op: 30.0, speedup_x: None }],
        )
        .unwrap();
        let text = std::fs::read_to_string(path).unwrap();
        assert!(text.starts_with("[\n"), "{text}");
        assert!(text.contains("\"bench\": \"a\""), "{text}");
        assert!(text.contains("\"speedup_x\": 2.500"), "{text}");
        assert!(text.contains("\"ns_per_op\": 30.000"), "{text}");
        assert!(!text.contains("\"ns_per_op\": 20.000"), "{text}");
        // And it parses with the in-tree JSON reader.
        let v = crate::coordinator::json::parse(&text).expect("valid json");
        assert_eq!(v.arr().unwrap().len(), 2);
        let _ = std::fs::remove_file(path);
    }

    #[test]
    fn fmt_time_ranges() {
        assert_eq!(fmt_time(13.9), "13.900 s");
        assert_eq!(fmt_time(0.000978), "0.978 ms");
        assert_eq!(fmt_time(0.0000005), "0.500 µs");
    }
}
