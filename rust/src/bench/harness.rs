//! Minimal wall-clock benchmark harness (criterion is not in the offline
//! crate set). Deterministic workloads + median-of-N timing with warm-up,
//! which is also how the paper measures: "avoiding cold misses and
//! averaging over 10 executions" (§7.2).

use std::time::Instant;

/// Result of a timed run.
#[derive(Debug, Clone, Copy)]
pub struct Report {
    pub iters: u32,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub std_s: f64,
}

impl Report {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
}

impl std::fmt::Display for Report {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "mean {:.3} ms  (min {:.3}, max {:.3}, σ {:.3}, n={})",
            self.mean_s * 1e3,
            self.min_s * 1e3,
            self.max_s * 1e3,
            self.std_s * 1e3,
            self.iters
        )
    }
}

/// Time `f` with `warmup` discarded runs and `iters` measured runs.
pub fn time<F: FnMut()>(warmup: u32, iters: u32, mut f: F) -> Report {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters as usize);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    let n = samples.len() as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    Report {
        iters: iters.max(1),
        mean_s: mean,
        min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().copied().fold(0.0, f64::max),
        std_s: var.sqrt(),
    }
}

/// Named benchmark entry for `cargo bench` binaries: prints a criterion-ish
/// line `name ... mean X ms`.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, f: F) -> Report {
    let r = time(warmup, iters, f);
    println!("{name:<52} {r}");
    r
}

/// Pretty-print a table: header row + aligned columns.
pub fn print_table(title: &str, header: &[&str], rows: &[Vec<String>]) {
    println!("\n== {title} ==");
    let mut widths: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    println!("{}", fmt_row(&header.iter().map(|s| s.to_string()).collect::<Vec<_>>()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Write rows as CSV (for EXPERIMENTS.md provenance and plotting).
pub fn write_csv(
    path: &str,
    header: &[&str],
    rows: &[Vec<String>],
) -> std::io::Result<()> {
    use std::io::Write;
    if let Some(dir) = std::path::Path::new(path).parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(f, "{}", header.join(","))?;
    for row in rows {
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Engineering formatting for seconds, paper-style ("0.978 ms", "13.9 s").
pub fn fmt_time(seconds: f64) -> String {
    if seconds >= 1.0 {
        format!("{seconds:.3} s")
    } else if seconds >= 1e-4 {
        // The paper prints sub-millisecond GEMM times in ms ("0.978 ms").
        format!("{:.3} ms", seconds * 1e3)
    } else {
        format!("{:.3} µs", seconds * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_reports_sane_stats() {
        let mut x = 0u64;
        let r = time(1, 5, || {
            for i in 0..10_000u64 {
                x = x.wrapping_add(i);
            }
        });
        assert_eq!(r.iters, 5);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s);
        assert!(std::hint::black_box(x) != 1);
    }

    #[test]
    fn fmt_time_ranges() {
        assert_eq!(fmt_time(13.9), "13.900 s");
        assert_eq!(fmt_time(0.000978), "0.978 ms");
        assert_eq!(fmt_time(0.0000005), "0.500 µs");
    }
}
