//! Model of the VividSparks RacEr GPGPU comparison row in Table 7.
//!
//! The hardware (512 CPUs @ 300 MHz, Posit32 without quire) is not
//! available; the paper publishes five measurements, so the comparison row
//! is regenerated from a least-squares fit of `t(n) = c₀ + c₁·n² + c₂·n³`
//! to those published points. This keeps the crossover analysis (PERCIVAL
//! up to 8× faster on small matrices, §8) reproducible without the device.

/// The paper's published measurements: (n, seconds).
pub const PAPER_POINTS: [(usize, f64); 5] =
    [(16, 7.95e-3), (32, 48.9e-3), (64, 0.345), (128, 2.63), (256, 21.1)];

/// Fitted model coefficients.
#[derive(Debug, Clone, Copy)]
pub struct RacerModel {
    pub c0: f64,
    pub c1: f64,
    pub c2: f64,
}

impl RacerModel {
    /// Least-squares fit over the published points, weighted by 1/t so the
    /// *relative* error is minimised (the points span 3.4 decades).
    pub fn fit() -> Self {
        // Design matrix rows: [1, n², n³]/t against target 1;
        // solve Aᵀ A x = Aᵀ b (3×3).
        let mut ata = [[0.0f64; 3]; 3];
        let mut atb = [0.0f64; 3];
        for (n, t) in PAPER_POINTS {
            let row = [1.0 / t, (n * n) as f64 / t, (n * n * n) as f64 / t];
            for i in 0..3 {
                for j in 0..3 {
                    ata[i][j] += row[i] * row[j];
                }
                atb[i] += row[i]; // target = t/t = 1
            }
        }
        let x = solve3(ata, atb);
        Self { c0: x[0], c1: x[1], c2: x[2] }
    }

    /// Predicted GEMM time in seconds.
    pub fn predict(&self, n: usize) -> f64 {
        self.c0 + self.c1 * (n * n) as f64 + self.c2 * (n * n * n) as f64
    }
}

/// Solve a 3×3 linear system by Gaussian elimination with partial pivoting.
fn solve3(mut a: [[f64; 3]; 3], mut b: [f64; 3]) -> [f64; 3] {
    for col in 0..3 {
        // Pivot.
        let mut p = col;
        for r in col + 1..3 {
            if a[r][col].abs() > a[p][col].abs() {
                p = r;
            }
        }
        a.swap(col, p);
        b.swap(col, p);
        let d = a[col][col];
        assert!(d.abs() > 1e-30, "singular system");
        for r in 0..3 {
            if r == col {
                continue;
            }
            let f = a[r][col] / d;
            for c in 0..3 {
                a[r][c] -= f * a[col][c];
            }
            b[r] -= f * b[col];
        }
    }
    [b[0] / a[0][0], b[1] / a[1][1], b[2] / a[2][2]]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_reproduces_published_points() {
        let m = RacerModel::fit();
        for (n, t) in PAPER_POINTS {
            let p = m.predict(n);
            let rel = ((p - t) / t).abs();
            assert!(rel < 0.25, "n={n}: predicted {p:.4}, paper {t:.4} (rel {rel:.3})");
        }
        // The large sizes are essentially cubic — tight there.
        let p256 = m.predict(256);
        assert!(((p256 - 21.1) / 21.1).abs() < 0.02, "{p256}");
    }

    #[test]
    fn cubic_term_dominates_large_n() {
        let m = RacerModel::fit();
        assert!(m.c2 > 0.0);
        let cubic = m.c2 * 256f64.powi(3);
        assert!(cubic / m.predict(256) > 0.9);
    }

    #[test]
    fn solve3_identity() {
        let x = solve3([[2.0, 0.0, 0.0], [0.0, 4.0, 0.0], [0.0, 0.0, 8.0]], [2.0, 8.0, 32.0]);
        assert_eq!(x, [1.0, 2.0, 4.0]);
    }
}
