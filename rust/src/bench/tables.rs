//! Regenerators for every table and figure in the paper's evaluation:
//! Table 6 (GEMM MSE), Fig. 7 (MSE bars, [-1,1]), Table 7 (GEMM timing,
//! incl. the RacEr comparison row), Table 8 (max-pooling timing).
//!
//! Each regenerator prints the paper-shaped table and writes a CSV under
//! `results/` for EXPERIMENTS.md provenance.

use super::gemm::{run_gemm_sim, GemmVariant};
use super::harness::{fmt_time, print_table, write_csv};
use super::maxpool::{run_pool_sim, PoolConfig, PoolFormat};
use super::mse::{table6_cell, NativeKind};
use super::racer::RacerModel;
use crate::core::CoreConfig;
use crate::testing::Rng;

/// Default matrix sizes (paper: 16..256).
pub const SIZES: [usize; 5] = [16, 32, 64, 128, 256];
/// Quick-mode (CI) sizes for the paper's IEEE-vs-posit Table 7 sweep.
pub const QUICK_SIZES: [usize; 3] = [16, 32, 64];
/// Quick-mode sizes for the posit sim rows: n=128 became affordable in
/// CI once the superblock engine landed, so the multi-width posit rows
/// (quire + no-quire) extend one size further than the IEEE sweep.
pub const QUICK_POSIT_SIZES: [usize; 4] = [16, 32, 64, 128];
/// Input ranges [-10^i, 10^i], i ∈ {-1, 0, 1, 2, 3} (paper §7.1).
pub const RANGES: [i32; 5] = [-1, 0, 1, 2, 3];
/// Seed used across all published runs.
pub const SEED: u64 = 0x5EED_2022;

/// Table 6: GEMM MSE of each format vs f64, 5 ranges × 4 kinds × sizes.
pub fn table6(sizes: &[usize], out_csv: Option<&str>) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    for exp10 in RANGES {
        for kind in NativeKind::TABLE6 {
            let mut row = vec![format!("[-1e{exp10}, 1e{exp10}]"), kind.label().to_string()];
            for &n in sizes {
                let m = table6_cell(kind, n, exp10, SEED);
                row.push(format!("{m:.3e}"));
            }
            rows.push(row);
        }
    }
    let mut header = vec!["input", "format"];
    let size_labels: Vec<String> = sizes.iter().map(|n| format!("{n}x{n}")).collect();
    header.extend(size_labels.iter().map(|s| s.as_str()));
    print_table("Table 6 — GEMM MSE vs 64-bit IEEE golden", &header, &rows);
    if let Some(path) = out_csv {
        let _ = write_csv(path, &header, &rows);
    }
    rows
}

/// Fig. 7: the [-1,1] block of Table 6 as a log-scale series (printed as
/// an ASCII chart + CSV: the bar chart's underlying numbers).
pub fn fig7(sizes: &[usize], out_csv: Option<&str>) -> Vec<Vec<String>> {
    let kinds = NativeKind::TABLE6;
    let mut rows = Vec::new();
    for &n in sizes {
        let mut row = vec![format!("{n}x{n}")];
        for kind in kinds {
            row.push(format!("{:.3e}", table6_cell(kind, n, 0, SEED)));
        }
        rows.push(row);
    }
    let header: Vec<&str> =
        std::iter::once("size").chain(kinds.iter().map(|k| k.label())).collect();
    print_table("Fig. 7 — GEMM MSE, inputs in [-1, 1] (log scale)", &header, &rows);
    // ASCII bars: log10(MSE) mapped to width.
    println!("log10(MSE), lower (further left) is better:");
    for (i, &n) in sizes.iter().enumerate() {
        for (j, kind) in kinds.iter().enumerate() {
            let v: f64 = rows[i][j + 1].parse().unwrap();
            let l = v.log10(); // ≈ -12 … -20
            let width = ((l + 22.0).max(0.0) * 4.0) as usize;
            println!("  {:>9} {:<20} {} {:.2}", format!("{n}x{n}"), kind.label(), "#".repeat(width), l);
        }
    }
    if let Some(path) = out_csv {
        let _ = write_csv(path, &header, &rows);
    }
    rows
}

/// Table 7: simulated GEMM wall-clock per variant and size + RacEr model.
/// Timing is input-independent in the model, so one measured run per cell
/// (after a warm-up run, matching the paper's no-cold-miss protocol).
/// Beyond the paper's six rows, the multi-width extension appends one row
/// per posit width (8/16/64-bit, quire and non-quire) so the simulated
/// timing story spans the same four formats the kernels do.
pub fn table7(cfg: CoreConfig, sizes: &[usize], out_csv: Option<&str>) -> Vec<Vec<String>> {
    let mut rng = Rng::new(SEED);
    let mut rows = Vec::new();
    let mut secs: Vec<Vec<f64>> = Vec::new();
    for v in GemmVariant::ALL.into_iter().chain(GemmVariant::POSIT_EXT) {
        let mut row = vec![v.label().to_string()];
        let mut srow = Vec::new();
        for &n in sizes {
            let a = super::gemm::gen_matrix(&mut rng, n, 0);
            let b = super::gemm::gen_matrix(&mut rng, n, 0);
            let run = run_gemm_sim(cfg, v, n, &a, &b, true);
            row.push(fmt_time(run.seconds));
            srow.push(run.seconds);
        }
        rows.push(row);
        secs.push(srow);
    }
    // RacEr comparison row (fitted model of the published column).
    let racer = RacerModel::fit();
    let mut row = vec!["VividSparks Posit32 no quire".to_string()];
    for &n in sizes {
        row.push(fmt_time(racer.predict(n)));
    }
    rows.push(row);
    let mut header = vec!["format"];
    let size_labels: Vec<String> = sizes.iter().map(|n| format!("{n}x{n}")).collect();
    header.extend(size_labels.iter().map(|s| s.as_str()));
    print_table("Table 7 — GEMM timing (simulated CVA6/PERCIVAL @ 50 MHz)", &header, &rows);
    if let Some(path) = out_csv {
        let _ = write_csv(path, &header, &rows);
    }
    rows
}

/// Table 8: max-pooling timing for the three DNN layers × three formats.
pub fn table8(cfg: CoreConfig, out_csv: Option<&str>) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    for layer in PoolConfig::ALL {
        let mut row = vec![layer.name.to_string()];
        for fmt in [PoolFormat::F32, PoolFormat::F64, PoolFormat::P32] {
            let run = run_pool_sim(cfg, fmt, &layer, true);
            row.push(fmt_time(run.seconds));
        }
        rows.push(row);
    }
    let header = vec!["max-pooling layer", "32-bit float", "64-bit float", "Posit32"];
    print_table("Table 8 — max-pooling timing (simulated @ 50 MHz)", &header, &rows);
    if let Some(path) = out_csv {
        let _ = write_csv(path, &header, &rows);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_small_has_expected_shape() {
        let rows = table6(&[16], None);
        // 5 ranges × 5 kinds (the four paper kinds + the Posit64 row).
        assert_eq!(rows.len(), 25);
        // In the [-1,1] block (range 0 → rows 5..10, kind order: IEEE,
        // Posit32, IEEE-noF, Posit-noQ, Posit64), Posit32 must beat every
        // 32-bit kind and Posit64 must beat everything.
        let block = &rows[5..10];
        let vals: Vec<f64> = block.iter().map(|r| r[2].parse().unwrap()).collect();
        assert!(vals[1] < vals[0] && vals[1] < vals[2] && vals[1] < vals[3]);
        assert!(vals[4] < vals[1], "Posit64 {} !< Posit32 {}", vals[4], vals[1]);
    }

    #[test]
    fn table7_quick_shape() {
        let cfg = CoreConfig { mem_size: 1 << 22, ..Default::default() };
        let rows = table7(cfg, &[16], None);
        // 6 paper variants + 6 multi-width posit rows + RacEr.
        assert_eq!(rows.len(), 13);
        // Fused beats unfused for every format (paper §7.2).
        let parse = |s: &str| -> f64 {
            let (v, unit) = s.split_once(' ').unwrap();
            let v: f64 = v.parse().unwrap();
            match unit {
                "s" => v,
                "ms" => v * 1e-3,
                _ => v * 1e-6,
            }
        };
        let fused_f32 = parse(&rows[0][1]);
        let unfused_f32 = parse(&rows[3][1]);
        assert!(fused_f32 < unfused_f32);
        let quire = parse(&rows[2][1]);
        let noquire = parse(&rows[5][1]);
        assert!(quire < noquire);
        // The multi-width rows follow in POSIT_EXT order; the quire wins
        // over mul+add at every width, and the Posit64 quire row is slower
        // than the Posit32 one (width-scaled PAU + 8-byte traffic).
        assert_eq!(rows[6][0], "Posit8");
        assert_eq!(rows[11][0], "Posit64 no quire");
        for w in [6, 8, 10] {
            assert!(parse(&rows[w][1]) < parse(&rows[w + 1][1]), "row {w}");
        }
        assert!(parse(&rows[10][1]) > parse(&rows[2][1]), "p64 quire !> p32 quire");
    }
}
