//! Accuracy study (paper §7.1, Table 6 & Fig. 7): GEMM Mean Squared Error
//! of each 32-bit format against the 64-bit IEEE golden result.
//!
//! These run on the *native* arithmetic paths (host IEEE and
//! [`crate::posit`]) rather than the core simulator — the semantics are
//! bit-identical (pinned by `bench::gemm::tests::simulated_matches_native_bitwise`)
//! and the native path makes the 256×256 sweep fast enough to regenerate
//! the full table in seconds.

use crate::kernels;
use crate::kernels::gemm::gemm_quire_scalar_gen;
use crate::posit::convert::{from_f64_n, to_f64_n};
use crate::posit::{Posit32, P64};
use crate::testing::Rng;

/// Native GEMM arithmetic kinds (mirror of [`super::gemm::GemmVariant`],
/// plus the 64-bit posit row the `PositFormat` refactor enables).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum NativeKind {
    F32Fused,
    F32Unfused,
    F64Fused,
    F64Unfused,
    P32Quire,
    P32NoQuire,
    /// Posit⟨64,2⟩ with its 1024-bit quire (Big-PERCIVAL configuration):
    /// extends the paper's Table-6/9-style accuracy comparison to 64 bits,
    /// where the posit matches the f64 golden at the golden's own noise
    /// floor.
    P64Quire,
}

impl NativeKind {
    /// Table 6 row order and labels (the Posit64 row extends the paper's
    /// table; the original four kinds keep their order).
    pub const TABLE6: [NativeKind; 5] = [
        NativeKind::F32Fused,
        NativeKind::P32Quire,
        NativeKind::F32Unfused,
        NativeKind::P32NoQuire,
        NativeKind::P64Quire,
    ];

    pub fn label(&self) -> &'static str {
        match self {
            NativeKind::F32Fused => "IEEE 754",
            NativeKind::P32Quire => "Posit32",
            NativeKind::F32Unfused => "IEEE 754 no FMADD",
            NativeKind::P32NoQuire => "Posit32 no quire",
            NativeKind::F64Fused => "IEEE 754 f64",
            NativeKind::F64Unfused => "IEEE 754 f64 no FMADD",
            NativeKind::P64Quire => "Posit64",
        }
    }
}

/// Run an n×n GEMM in the given arithmetic. Inputs are f64 master values;
/// each kind converts them to its storage format first (as the paper does
/// with SoftPosit), computes C = A·B, and returns C widened to f64.
pub fn gemm_native(kind: NativeKind, n: usize, af: &[f64], bf: &[f64]) -> Vec<f64> {
    assert_eq!(af.len(), n * n);
    assert_eq!(bf.len(), n * n);
    let mut c = vec![0.0f64; n * n];
    match kind {
        NativeKind::F64Fused => {
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0f64;
                    for k in 0..n {
                        acc = af[i * n + k].mul_add(bf[k * n + j], acc);
                    }
                    c[i * n + j] = acc;
                }
            }
        }
        NativeKind::F64Unfused => {
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0f64;
                    for k in 0..n {
                        acc += af[i * n + k] * bf[k * n + j];
                    }
                    c[i * n + j] = acc;
                }
            }
        }
        NativeKind::F32Fused => {
            let a: Vec<f32> = af.iter().map(|v| *v as f32).collect();
            let b: Vec<f32> = bf.iter().map(|v| *v as f32).collect();
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for k in 0..n {
                        acc = a[i * n + k].mul_add(b[k * n + j], acc);
                    }
                    c[i * n + j] = acc as f64;
                }
            }
        }
        NativeKind::F32Unfused => {
            let a: Vec<f32> = af.iter().map(|v| *v as f32).collect();
            let b: Vec<f32> = bf.iter().map(|v| *v as f32).collect();
            for i in 0..n {
                for j in 0..n {
                    let mut acc = 0.0f32;
                    for k in 0..n {
                        acc += a[i * n + k] * b[k * n + j];
                    }
                    c[i * n + j] = acc as f64;
                }
            }
        }
        NativeKind::P32Quire => {
            // Batched kernel path: decode-once, windowed quire, row-parallel
            // (bit-identical to the scalar oracle — see
            // `kernel_path_matches_scalar_oracle` and tests/kernel_equiv.rs).
            let a: Vec<u32> = af.iter().map(|v| Posit32::from_f64(*v).bits()).collect();
            let b: Vec<u32> = bf.iter().map(|v| Posit32::from_f64(*v).bits()).collect();
            let bits = kernels::gemm::gemm_p32_quire(n, &a, &b);
            for (ci, v) in c.iter_mut().zip(&bits) {
                *ci = Posit32(*v).to_f64();
            }
        }
        NativeKind::P32NoQuire => {
            let a: Vec<u32> = af.iter().map(|v| Posit32::from_f64(*v).bits()).collect();
            let b: Vec<u32> = bf.iter().map(|v| Posit32::from_f64(*v).bits()).collect();
            let bits = kernels::gemm::gemm_p32_noquire(n, &a, &b);
            for (ci, v) in c.iter_mut().zip(&bits) {
                *ci = Posit32(*v).to_f64();
            }
        }
        NativeKind::P64Quire => {
            // The format-generic kernel driver instantiated at 64 bits:
            // decode-once, 1024-bit windowed quire, row-parallel.
            let a: Vec<u64> = af.iter().map(|v| from_f64_n(64, *v)).collect();
            let b: Vec<u64> = bf.iter().map(|v| from_f64_n(64, *v)).collect();
            let bits = kernels::gemm::gemm_quire::<P64>(n, &a, &b);
            for (ci, v) in c.iter_mut().zip(&bits) {
                *ci = to_f64_n(64, *v);
            }
        }
    }
    c
}

/// The pre-kernel scalar GEMM, kept as the bit-exactness oracle for the
/// posit kinds (the float kinds have no kernel/scalar split and delegate
/// to [`gemm_native`]). The scalar loops themselves live once, in
/// [`kernels::gemm`].
pub fn gemm_native_scalar(kind: NativeKind, n: usize, af: &[f64], bf: &[f64]) -> Vec<f64> {
    let scalar = |f: fn(usize, &[u32], &[u32]) -> Vec<u32>| {
        let a: Vec<u32> = af.iter().map(|v| Posit32::from_f64(*v).bits()).collect();
        let b: Vec<u32> = bf.iter().map(|v| Posit32::from_f64(*v).bits()).collect();
        f(n, &a, &b).iter().map(|v| Posit32(*v).to_f64()).collect()
    };
    match kind {
        NativeKind::P32Quire => scalar(kernels::gemm::gemm_p32_quire_scalar),
        NativeKind::P32NoQuire => scalar(kernels::gemm::gemm_p32_noquire_scalar),
        NativeKind::P64Quire => {
            let a: Vec<u64> = af.iter().map(|v| from_f64_n(64, *v)).collect();
            let b: Vec<u64> = bf.iter().map(|v| from_f64_n(64, *v)).collect();
            gemm_quire_scalar_gen::<P64>(n, &a, &b).iter().map(|v| to_f64_n(64, *v)).collect()
        }
        _ => gemm_native(kind, n, af, bf),
    }
}

/// Mean squared error against a golden vector.
pub fn mse(got: &[f64], golden: &[f64]) -> f64 {
    assert_eq!(got.len(), golden.len());
    got.iter()
        .zip(golden)
        .map(|(g, r)| {
            let d = g - r;
            d * d
        })
        .sum::<f64>()
        / got.len() as f64
}

/// One Table 6 cell: MSE of `kind` vs the f64-FMA golden, for a seeded
/// uniform input in `[-10^exp10, 10^exp10]`.
pub fn table6_cell(kind: NativeKind, n: usize, exp10: i32, seed: u64) -> f64 {
    let mut rng = Rng::new(seed ^ ((exp10 as u64) << 32) ^ (n as u64));
    let a = super::gemm::gen_matrix(&mut rng, n, exp10);
    let b = super::gemm::gen_matrix(&mut rng, n, exp10);
    let golden = gemm_native(NativeKind::F64Fused, n, &a, &b);
    let got = gemm_native(kind, n, &a, &b);
    mse(&got, &golden)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kernel_path_matches_scalar_oracle() {
        // gemm_native's posit kinds route through the batched kernels;
        // they must reproduce the pre-kernel scalar loops bit-for-bit
        // (f64 widening is exact, so f64 equality pins the bits).
        let n = 24;
        let mut rng = Rng::new(0x04AC1E);
        let a = super::super::gemm::gen_matrix(&mut rng, n, 1);
        let b = super::super::gemm::gen_matrix(&mut rng, n, 1);
        for kind in [NativeKind::P32Quire, NativeKind::P32NoQuire, NativeKind::P64Quire] {
            assert_eq!(
                gemm_native(kind, n, &a, &b),
                gemm_native_scalar(kind, n, &a, &b),
                "{kind:?}"
            );
        }
    }

    #[test]
    fn p64_quire_tracks_the_golden_closest() {
        // The 64-bit posit + 1024-bit quire row: its disagreement with the
        // f64-FMA golden is the golden's own rounding noise, orders of
        // magnitude below every 32-bit kind.
        let n = 32;
        let mut rng = Rng::new(0x64AC);
        let a = super::super::gemm::gen_matrix(&mut rng, n, 0);
        let b = super::super::gemm::gen_matrix(&mut rng, n, 0);
        let golden = gemm_native(NativeKind::F64Fused, n, &a, &b);
        let m64 = mse(&gemm_native(NativeKind::P64Quire, n, &a, &b), &golden);
        let m32 = mse(&gemm_native(NativeKind::P32Quire, n, &a, &b), &golden);
        let mf = mse(&gemm_native(NativeKind::F32Fused, n, &a, &b), &golden);
        assert!(m64 < m32, "p64 {m64} !< p32 {m32}");
        assert!(m64 < mf / 1e6, "p64 {m64} not ≪ f32 {mf}");
    }

    #[test]
    fn golden_is_zero_error_against_itself() {
        let mut rng = Rng::new(1);
        let a = super::super::gemm::gen_matrix(&mut rng, 8, 0);
        let b = super::super::gemm::gen_matrix(&mut rng, 8, 0);
        let g = gemm_native(NativeKind::F64Fused, 8, &a, &b);
        assert_eq!(mse(&g, &g), 0.0);
    }

    #[test]
    fn quire_beats_no_quire_beats_nothing() {
        // The paper's headline ordering for [-1,1] inputs:
        // MSE(posit+quire) < MSE(posit) < MSE(f32) (Table 6).
        let n = 32;
        let mut rng = Rng::new(42);
        let a = super::super::gemm::gen_matrix(&mut rng, n, 0);
        let b = super::super::gemm::gen_matrix(&mut rng, n, 0);
        let golden = gemm_native(NativeKind::F64Fused, n, &a, &b);
        let mq = mse(&gemm_native(NativeKind::P32Quire, n, &a, &b), &golden);
        let mnq = mse(&gemm_native(NativeKind::P32NoQuire, n, &a, &b), &golden);
        let mf = mse(&gemm_native(NativeKind::F32Fused, n, &a, &b), &golden);
        assert!(mq < mnq, "quire {mq} !< no-quire {mnq}");
        assert!(mnq < mf, "no-quire {mnq} !< f32 {mf}");
        // And the quire gap is orders of magnitude (paper: ~3-4 orders
        // for larger n; at n=32 expect ≥ 2).
        assert!(mf / mq > 100.0, "f32/quire ratio only {}", mf / mq);
    }

    #[test]
    fn paper_golden_zone_crossover() {
        // §7.1: for inputs in [-1000, 1000] the no-quire posit falls
        // *behind* floats (outputs leave the posit golden zone), while the
        // quire version stays ahead — the paper's Table 6 bottom block.
        let n = 64;
        let mut rng = Rng::new(7);
        let a = super::super::gemm::gen_matrix(&mut rng, n, 3);
        let b = super::super::gemm::gen_matrix(&mut rng, n, 3);
        let golden = gemm_native(NativeKind::F64Fused, n, &a, &b);
        let mq = mse(&gemm_native(NativeKind::P32Quire, n, &a, &b), &golden);
        let mnq = mse(&gemm_native(NativeKind::P32NoQuire, n, &a, &b), &golden);
        let mf = mse(&gemm_native(NativeKind::F32Fused, n, &a, &b), &golden);
        assert!(mnq > mf, "no-quire {mnq} should exceed f32 {mf} at [-1e3,1e3]");
        assert!(mq < mf, "quire {mq} must still beat f32 {mf}");
    }

    #[test]
    fn mse_grows_with_matrix_size() {
        // Float error accumulates with n; quire error stays near one-ulp.
        let kinds = [NativeKind::F32Fused, NativeKind::P32Quire];
        for kind in kinds {
            let m16 = table6_cell(kind, 16, 0, 99);
            let m64 = table6_cell(kind, 64, 0, 99);
            assert!(m64 > m16 * 0.5, "{kind:?}: m16={m16} m64={m64}");
        }
        let f16 = table6_cell(NativeKind::F32Fused, 16, 0, 99);
        let q16 = table6_cell(NativeKind::P32Quire, 16, 0, 99);
        assert!(f16 / q16 > 50.0);
    }
}
