//! Benchmark substrate: workload generators, the accuracy (MSE) study, the
//! table/figure regenerators, the RacEr comparison model, and a minimal
//! wall-clock harness (criterion replacement).
//!
//! Map to the paper's evaluation:
//! - [`mse`] + [`tables::table6`]/[`tables::fig7`] → Table 6, Fig. 7
//! - [`gemm`] + [`tables::table7`] + [`racer`]     → Table 7
//! - [`maxpool`] + [`tables::table8`]              → Table 8

pub mod gemm;
pub mod harness;
pub mod maxpool;
pub mod mse;
pub mod racer;
pub mod tables;
