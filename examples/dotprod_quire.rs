//! Dot products with and without the quire — the paper's §7.1 accuracy
//! story in miniature, run both natively and on the simulated PERCIVAL
//! core executing the actual Fig. 6 Xposit kernel.

use percival::core::{Core, CoreConfig};
use percival::isa::asm::assemble;
use percival::posit::{ops, Posit32};
use percival::testing::Rng;

fn main() {
    let n = 1024usize;
    let mut rng = Rng::new(0xD07);
    // A vector pair engineered to cancel: each +x·x pairs with x·(−x+ε/x),
    // so the true dot product is just the sum of the tiny residuals ε.
    let mut af = Vec::new();
    let mut bf = Vec::new();
    for _ in 0..n / 2 {
        let x = rng.range_f64(1e3, 1e4);
        let eps = rng.range_f64(-1.0, 1.0);
        af.push(x);
        bf.push(x);
        af.push(x);
        bf.push(-x + eps / x);
    }
    let a: Vec<u32> = af.iter().map(|v| Posit32::from_f64(*v).bits()).collect();
    let b: Vec<u32> = bf.iter().map(|v| Posit32::from_f64(*v).bits()).collect();
    // Golden reference over the values the hardware actually sees (the
    // posit-rounded inputs, as in the paper's §7.1 protocol): an f64 dot of
    // exactly-decoded posits.
    let exact: f64 = a
        .iter()
        .zip(&b)
        .map(|(x, y)| Posit32(*x).to_f64() * Posit32(*y).to_f64())
        .sum();

    // Native, with quire — the decode-once kernel path (bit-identical to
    // a scalar QMADD loop; pinned by tests/kernel_equiv.rs).
    let with_quire = Posit32(percival::kernels::dot_p32_quire(&a, &b)).to_f64();

    // Native, without quire (pmul + padd).
    let mut acc = 0u32;
    for (x, y) in a.iter().zip(&b) {
        acc = ops::add::<32>(acc, ops::mul::<32>(*x, *y));
    }
    let without = Posit32(acc).to_f64();

    // f32 baseline.
    let f32dot: f32 = af.iter().zip(&bf).map(|(x, y)| (*x as f32) * (*y as f32)).sum();

    println!("golden (f64 over decoded posits) = {exact:.9}");
    println!("posit32 + quire      = {with_quire:.9}   (err {:.3e})", (with_quire - exact).abs());
    println!("posit32 no quire     = {without:.9}   (err {:.3e})", (without - exact).abs());
    println!("f32                  = {f32dot:.9}   (err {:.3e})", (f32dot as f64 - exact).abs());

    // Now the same dot product as the paper's Fig. 6 kernel on the core.
    let prog = assemble(
        r#"
        qclr.s
    loop:
        plw p0, 0(a0)
        plw p1, 0(a1)
        qmadd.s p0, p1
        addi a0, a0, 4
        addi a1, a1, 4
        addi a2, a2, -1
        bnez a2, loop
        qround.s p2
        psw p2, 0(a3)
        ecall
    "#,
    )
    .expect("kernel assembles");
    let mut core = Core::new(CoreConfig::default());
    core.load_program(&prog);
    core.mem.write_u32_slice(0x1_0000, &a);
    core.mem.write_u32_slice(0x2_0000, &b);
    core.ctx.x[10] = 0x1_0000;
    core.ctx.x[11] = 0x2_0000;
    core.ctx.x[12] = n as u64;
    core.ctx.x[13] = 0x3_0000;
    let stats = core.run();
    let sim = Posit32(core.mem.read_u32(0x3_0000)).to_f64();
    println!(
        "\nsimulated PERCIVAL (Fig. 6 kernel): result {sim:.9}, {} cycles = {} @ 50 MHz (IPC {:.2})",
        stats.cycles,
        percival::bench::harness::fmt_time(stats.seconds(&core.cfg)),
        stats.ipc()
    );
    assert_eq!(sim, with_quire, "simulator must match the native quire bitwise");
    println!("simulator ≡ native library: bit-exact ✓");
}
