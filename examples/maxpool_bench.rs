//! Regenerate Table 8: max-pooling timing on the simulated PERCIVAL core
//! for the LeNet-5 / AlexNet / ResNet-50 layers in f32, f64 and posit32.

use percival::bench::tables;
use percival::core::CoreConfig;

fn main() {
    let rows = tables::table8(CoreConfig::default(), Some("results/table8.csv"));
    // The paper's claim: posit32 ≈ f32, f64 slower by 1.4–1.7×.
    println!("\nParsed claims:");
    for row in &rows {
        let parse = |s: &str| -> f64 {
            let (v, unit) = s.split_once(' ').unwrap();
            let v: f64 = v.parse().unwrap();
            match unit {
                "s" => v,
                "ms" => v * 1e-3,
                _ => v * 1e-6,
            }
        };
        let (f32t, f64t, p32t) = (parse(&row[1]), parse(&row[2]), parse(&row[3]));
        println!(
            "  {:<24} p32/f32 = {:.3}  f64/f32 = {:.2}",
            row[0],
            p32t / f32t,
            f64t / f32t
        );
    }
}
