//! Regenerate the synthesis section (§6): Tables 3, 4, 5, the headline
//! ratios, and the design-choice ablations.

use percival::synth::report;

fn main() {
    report::table3(Some("results/table3.csv"));
    report::table4(Some("results/table4.csv"));
    report::table5(Some("results/table5.csv"));
    report::ratios();
    report::ablations();
    println!("\nCSV written to results/table{{3,4,5}}.csv");
}
