//! End-to-end system driver — proves all layers compose on a real workload.
//!
//! For a set of GEMM workloads this runs the SAME posit computation through
//! the full stack and requires bit-identical results everywhere:
//!
//!   1. **L3 simulator** — the paper's Fig. 6 Xposit kernel, assembled by
//!      `isa::asm` and executed on the cycle-accurate CVA6 model (gives the
//!      paper-scale timing).
//!   2. **Native library** — `posit::Quire32` (the PAU's arithmetic).
//!   3. **PJRT artifact** — the L1 Pallas quire kernel, written in Python,
//!      AOT-lowered by `make artifacts`, loaded and executed from Rust.
//!
//! It then reports the accuracy of each numeric format against the f64
//! golden result (the paper's §7.1 protocol) and the simulated timing
//! (§7.2). Recorded in EXPERIMENTS.md §End-to-end.

use percival::bench::gemm::{gen_matrix, run_gemm_sim, GemmVariant};
use percival::bench::harness::fmt_time;
use percival::bench::mse::{gemm_native, mse, NativeKind};
use percival::coordinator::sched::{run_batch_parallel, run_batch_serial};
use percival::coordinator::{
    json, Backend, Client, ClientConfig, Coordinator, FaultPlan, Format, HartKill, Job, JobSpec,
    Priority, Server, ServerConfig, Service, ServiceConfig, SimPoolConfig,
};
use percival::core::CoreConfig;
use percival::posit::convert::from_f64_n;
use percival::posit::Posit32;
use percival::runtime::Runtime;
use percival::testing::Rng;

fn main() -> percival::error::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[usize] = if quick { &[8] } else { &[8, 16, 32] };
    let cfg = CoreConfig::default();
    let mut rng = Rng::new(0xE2E);

    println!("=== PERCIVAL end-to-end: L3 sim ⇄ native PAU ⇄ L1 Pallas/PJRT ===\n");
    let mut pjrt = Runtime::cpu("artifacts").ok();
    if pjrt.is_none() {
        println!("NOTE: PJRT unavailable; artifact leg will be skipped");
    }

    let mut pjrt_executes = false;
    for &n in sizes {
        let af = gen_matrix(&mut rng, n, 0);
        let bf = gen_matrix(&mut rng, n, 0);
        let a: Vec<u32> = af.iter().map(|v| Posit32::from_f64(*v).bits()).collect();
        let b: Vec<u32> = bf.iter().map(|v| Posit32::from_f64(*v).bits()).collect();

        // Leg 1: cycle-accurate simulator running the Fig. 6 kernel.
        let sim = run_gemm_sim(cfg, GemmVariant::P32Quire, n, &af, &bf, true);
        let sim_bits: Vec<u32> =
            sim.result.iter().map(|v| Posit32::from_f64(*v).bits()).collect();

        // Leg 2: native library.
        let native = percival::runtime::native_gemm_quire(n, &a, &b);

        // Leg 3: PJRT artifact (compiled from the Python Pallas kernel).
        // Skipped when the runtime cannot execute (default builds get the
        // stub) or the artifact is missing; a *real* runtime failing on a
        // present artifact still propagates loudly via `?`.
        let art = pjrt
            .as_mut()
            .filter(|rt| rt.can_execute() && rt.has_artifact(&format!("gemm_p32_quire_{n}")))
            .map(|rt| rt.gemm_p32("quire", n, &a, &b))
            .transpose()?;
        if art.is_some() {
            pjrt_executes = true;
        }

        assert_eq!(sim_bits, native, "simulator vs native disagree at n={n}");
        let legs = if let Some(art) = &art {
            assert_eq!(art, &native, "PJRT artifact vs native disagree at n={n}");
            "sim ≡ native ≡ pjrt"
        } else {
            "sim ≡ native (pjrt leg unavailable)"
        };

        // Accuracy vs f64 golden, posit vs f32 (the §7.1 comparison).
        let golden = gemm_native(NativeKind::F64Fused, n, &af, &bf);
        let posit_vals: Vec<f64> = native.iter().map(|v| Posit32(*v).to_f64()).collect();
        let f32_vals = gemm_native(NativeKind::F32Fused, n, &af, &bf);
        let mse_p = mse(&posit_vals, &golden);
        let mse_f = mse(&f32_vals, &golden);

        println!(
            "n={n:<3} {legs} ✓   sim {} ({} cycles, IPC {:.2}, D$ miss {:.1}%)",
            fmt_time(sim.seconds),
            sim.stats.cycles,
            sim.stats.ipc(),
            100.0 * sim.stats.dcache_misses as f64
                / (sim.stats.dcache_hits + sim.stats.dcache_misses).max(1) as f64,
        );
        println!(
            "      MSE vs f64: posit32+quire {mse_p:.3e}  vs  f32 {mse_f:.3e}  (×{:.0} better)",
            mse_f / mse_p.max(f64::MIN_POSITIVE)
        );
    }

    // Coordinator-level cross-check (the L3 request path).
    println!("\n=== coordinator cross-check (4 workers) ===");
    let co = Coordinator::new(4, Some("artifacts".into()));
    let n = 8;
    let a: Vec<u32> =
        (0..n * n).map(|_| Posit32::from_f64(rng.range_f64(-1.0, 1.0)).bits()).collect();
    let b: Vec<u32> =
        (0..n * n).map(|_| Posit32::from_f64(rng.range_f64(-1.0, 1.0)).bits()).collect();
    // Include the PJRT backend only when a leg actually executed above
    // (artifact on disk AND a runtime that can run it).
    let backends: Vec<Backend> = if pjrt_executes {
        vec![Backend::Native, Backend::Sim, Backend::Pjrt]
    } else {
        vec![Backend::Native, Backend::Sim]
    };
    let results = co.cross_check(Job::GemmP32 { n, a, b, quire: true }, &backends)?;
    for r in &results {
        println!(
            "  {:?}: host {:.3} ms{}",
            r.backend,
            r.elapsed_s * 1e3,
            r.sim_seconds.map(|s| format!(", simulated {}", fmt_time(s))).unwrap_or_default()
        );
    }
    println!("metrics: {}", co.metrics.summary());

    // Multi-hart Sim scheduler: a mixed-format batch time-sliced over a
    // pool of simulated harts, with qsq/qlq quire spills at every
    // context switch (the paper-§8 OS scenario). The bits must still
    // match the Native backend exactly — contention moves time, not
    // arithmetic.
    println!("\n=== multi-hart Sim scheduler (mixed-format batch, quantum preemption) ===");
    let mut jobs = Vec::new();
    for fmt in Format::ALL {
        let w = fmt.width();
        let jn = 6;
        let a: Vec<u64> =
            (0..jn * jn).map(|_| from_f64_n(w, rng.range_f64(-1.0, 1.0))).collect();
        let b: Vec<u64> =
            (0..jn * jn).map(|_| from_f64_n(w, rng.range_f64(-1.0, 1.0))).collect();
        jobs.push(Job::Gemm { fmt, n: jn, a: a.clone(), b: b.clone(), quire: true });
        jobs.push(Job::Dot { fmt, a, b });
    }
    let specs: Vec<JobSpec> = jobs.iter().cloned().map(JobSpec::new).collect();
    let pool = SimPoolConfig { harts: 2, quantum: 400, ..Default::default() };
    let report = run_batch_serial(&specs, &pool)?;
    for (i, (job, out)) in jobs.iter().zip(&report.jobs).enumerate() {
        let native = co.run(job.clone(), Backend::Native)?;
        assert_eq!(out.bits64, native.bits64, "job {i} diverges from Native under preemption");
        println!(
            "  job {i:<2} {:<8} hart {}  completed at {}",
            out.fmt.name(),
            out.hart,
            fmt_time(out.completion_s)
        );
    }
    println!(
        "  makespan {} over {} harts ({} jobs, quantum {} instrs)",
        fmt_time(report.makespan_s),
        pool.harts,
        jobs.len(),
        pool.quantum
    );
    for (h, (hart, util)) in report.harts.iter().zip(report.utilization()).enumerate() {
        println!(
            "  hart {h}: {:>5.1}% utilized, {} jobs, {} ctx switches, {} spill cycles \
             ({:.2}% of its {} cycles)",
            100.0 * util,
            hart.jobs,
            hart.stats.ctx_switches,
            hart.stats.spill_cycles,
            100.0 * hart.stats.spill_cycles as f64 / hart.stats.cycles.max(1) as f64,
            hart.stats.cycles,
        );
    }

    // The same batch on the host-parallel pool: each simulated hart runs
    // on its own OS thread, and every bit, virtual cycle, and counter
    // must match the serial schedule exactly.
    let par = run_batch_parallel(&specs, &pool)?;
    assert_eq!(par.makespan_s, report.makespan_s, "parallel pool changed virtual time");
    for (i, (s, p)) in report.jobs.iter().zip(&par.jobs).enumerate() {
        assert_eq!(s.bits64, p.bits64, "job {i} bits diverge on the parallel pool");
        assert_eq!(s.completion_s, p.completion_s, "job {i} timing diverges");
    }
    println!("  host-parallel pool replayed the schedule bit- and cycle-exactly ✓");

    // Fault-injection leg: rerun the batch with checkpointing on and one
    // hart killed mid-flight. The orphaned jobs migrate to the survivor
    // and resume from their last checkpoint — and the bits must *still*
    // match the fault-free run exactly.
    println!("\n=== fault injection (hart 0 killed mid-batch, checkpoint recovery) ===");
    let faulty = SimPoolConfig {
        harts: 2,
        quantum: 400,
        checkpoint_quanta: 2,
        faults: FaultPlan {
            kill_harts: vec![HartKill { hart: 0, at_cycle: 2_000 }],
            ..Default::default()
        },
        ..Default::default()
    };
    let recovered = run_batch_serial(&specs, &faulty)?;
    for (i, (clean, out)) in report.jobs.iter().zip(&recovered.jobs).enumerate() {
        assert!(out.error.is_none(), "job {i} failed to recover: {:?}", out.error);
        assert_eq!(out.bits64, clean.bits64, "job {i} bits changed across hart failure");
    }
    let (migrations, retries, checkpoints) = recovered.jobs.iter().fold(
        (0u64, 0u64, 0u64),
        |(m, r, c), j| (m + j.migrations, r + j.retries, c + j.checkpoints),
    );
    println!(
        "  all {} jobs recovered bit-exactly: {migrations} migrations, \
         {retries} retries, {checkpoints} checkpoints",
        recovered.jobs.len()
    );
    for (h, hart) in recovered.harts.iter().enumerate() {
        println!(
            "  hart {h}: {} — {} jobs finished, {} migrated in, {} checkpoints, {} cycles",
            if hart.alive { "alive" } else { "KILLED" },
            hart.jobs,
            hart.stats.migrations,
            hart.stats.checkpoints,
            hart.stats.cycles,
        );
    }
    println!(
        "  makespan {} (vs {} fault-free, {:+.1}%)",
        fmt_time(recovered.makespan_s),
        fmt_time(report.makespan_s),
        100.0 * (recovered.makespan_s / report.makespan_s - 1.0),
    );

    co.shutdown();

    // Service leg: the long-running submission API. One high-priority
    // Sim job streams Queued → Started → Checkpointed* → Done, and both
    // the request and every event render through the versioned wire
    // schema (`coordinator::json`).
    println!("\n=== coordinator service (streaming submission API) ===");
    let svc = Service::new(ServiceConfig {
        native_workers: 2,
        pool: SimPoolConfig { harts: 2, quantum: 400, checkpoint_quanta: 2, ..Default::default() },
        ..Default::default()
    });
    let jn = 8;
    let a: Vec<u64> = (0..jn * jn).map(|_| from_f64_n(32, rng.range_f64(-1.0, 1.0))).collect();
    let b: Vec<u64> = (0..jn * jn).map(|_| from_f64_n(32, rng.range_f64(-1.0, 1.0))).collect();
    let spec = JobSpec::gemm(Format::P32, jn, a, b, true)
        .backend(Backend::Sim)
        .priority(Priority::High)
        .deadline(50_000_000);
    println!("  request: {}", json::job_request(&spec));
    let handle = svc.submit(spec)?;
    while let Some(ev) = handle.recv() {
        let terminal = ev.is_terminal();
        println!("  event:   {}", json::event_frame(&ev));
        if terminal {
            break;
        }
    }
    svc.shutdown();

    // Network leg: the line-delimited TCP transport in front of the
    // service, through a graceful drain and rolling restart. Server A
    // drains mid-batch into a snapshot; server B resumes the stranded
    // jobs under their original wire ids, and the results attached
    // across the restart still match the Native backend bit-for-bit.
    println!("\n=== network serving (TCP transport, drain + rolling restart) ===");
    let snap = std::env::temp_dir().join(format!("percival_e2e_{}.snap", std::process::id()));
    let _ = std::fs::remove_file(&snap);
    let net_pool =
        SimPoolConfig { harts: 2, quantum: 50, checkpoint_quanta: 1, ..Default::default() };
    let serve_cfg = || ServerConfig {
        service: ServiceConfig { native_workers: 1, pool: net_pool.clone(), ..Default::default() },
        snapshot_path: Some(snap.clone()),
        ..Default::default()
    };
    let start = |cfg: ServerConfig| {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").expect("bind loopback");
        let addr = listener.local_addr().expect("local addr");
        let server = Server::new(cfg);
        let srv = server.clone();
        (server, addr, std::thread::spawn(move || srv.serve(listener)))
    };
    let mut net_specs = Vec::new();
    for _ in 0..3 {
        let jn = 10;
        let a: Vec<u64> =
            (0..jn * jn).map(|_| from_f64_n(32, rng.range_f64(-1.0, 1.0))).collect();
        let b: Vec<u64> =
            (0..jn * jn).map(|_| from_f64_n(32, rng.range_f64(-1.0, 1.0))).collect();
        net_specs.push(JobSpec::gemm(Format::P32, jn, a, b, true).backend(Backend::Sim));
    }
    let co2 = Coordinator::new(1, None);
    let refs: Vec<Vec<u64>> = net_specs
        .iter()
        .map(|s| co2.run(s.job.clone(), Backend::Native).map(|r| r.bits64))
        .collect::<percival::error::Result<_>>()?;
    let (_a, addr_a, ha) = start(serve_cfg());
    let mut ca = Client::connect(ClientConfig::new(addr_a.to_string()))?;
    let ids: Vec<u64> = net_specs
        .iter()
        .map(|s| ca.submit(s))
        .collect::<percival::error::Result<_>>()?;
    ca.shutdown_server()?;
    let summary = ha.join().expect("serve A thread")?;
    println!(
        "  server A drained: {} in-flight job(s) snapshotted, {} already resolved",
        summary.drained, summary.resolved
    );
    let (srv_b, addr_b, hb) = start(serve_cfg());
    println!("  server B resumed {} job(s) from the drain snapshot", srv_b.resumed());
    let mut cb = Client::connect(ClientConfig::new(addr_b.to_string()))?;
    for (i, id) in ids.iter().enumerate() {
        let r = cb.wait(*id, std::time::Duration::from_secs(120))?;
        assert_eq!(r.bits64, refs[i], "net job {i} diverges from Native across restart");
    }
    println!(
        "  {} job(s) verified bit-identical across the restart ✓ (attach polls: {})",
        ids.len(),
        cb.stats.attach_polls
    );
    cb.shutdown_server()?;
    hb.join().expect("serve B thread")?;
    co2.shutdown();
    let _ = std::fs::remove_file(&snap);

    println!("\nEND-TO-END: all legs agree bit-for-bit ✓");
    Ok(())
}
