//! End-to-end system driver — proves all layers compose on a real workload.
//!
//! For a set of GEMM workloads this runs the SAME posit computation through
//! the full stack and requires bit-identical results everywhere:
//!
//!   1. **L3 simulator** — the paper's Fig. 6 Xposit kernel, assembled by
//!      `isa::asm` and executed on the cycle-accurate CVA6 model (gives the
//!      paper-scale timing).
//!   2. **Native library** — `posit::Quire32` (the PAU's arithmetic).
//!   3. **PJRT artifact** — the L1 Pallas quire kernel, written in Python,
//!      AOT-lowered by `make artifacts`, loaded and executed from Rust.
//!
//! It then reports the accuracy of each numeric format against the f64
//! golden result (the paper's §7.1 protocol) and the simulated timing
//! (§7.2). Recorded in EXPERIMENTS.md §End-to-end.

use percival::bench::gemm::{gen_matrix, run_gemm_sim, GemmVariant};
use percival::bench::harness::fmt_time;
use percival::bench::mse::{gemm_native, mse, NativeKind};
use percival::coordinator::{Backend, Coordinator, Job};
use percival::core::CoreConfig;
use percival::posit::Posit32;
use percival::runtime::Runtime;
use percival::testing::Rng;

fn main() -> percival::error::Result<()> {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[usize] = if quick { &[8] } else { &[8, 16, 32] };
    let cfg = CoreConfig::default();
    let mut rng = Rng::new(0xE2E);

    println!("=== PERCIVAL end-to-end: L3 sim ⇄ native PAU ⇄ L1 Pallas/PJRT ===\n");
    let mut pjrt = Runtime::cpu("artifacts").ok();
    if pjrt.is_none() {
        println!("NOTE: PJRT unavailable; artifact leg will be skipped");
    }

    let mut pjrt_executes = false;
    for &n in sizes {
        let af = gen_matrix(&mut rng, n, 0);
        let bf = gen_matrix(&mut rng, n, 0);
        let a: Vec<u32> = af.iter().map(|v| Posit32::from_f64(*v).bits()).collect();
        let b: Vec<u32> = bf.iter().map(|v| Posit32::from_f64(*v).bits()).collect();

        // Leg 1: cycle-accurate simulator running the Fig. 6 kernel.
        let sim = run_gemm_sim(cfg, GemmVariant::P32Quire, n, &af, &bf, true);
        let sim_bits: Vec<u32> =
            sim.result.iter().map(|v| Posit32::from_f64(*v).bits()).collect();

        // Leg 2: native library.
        let native = percival::runtime::native_gemm_quire(n, &a, &b);

        // Leg 3: PJRT artifact (compiled from the Python Pallas kernel).
        // Skipped when the runtime cannot execute (default builds get the
        // stub) or the artifact is missing; a *real* runtime failing on a
        // present artifact still propagates loudly via `?`.
        let art = pjrt
            .as_mut()
            .filter(|rt| rt.can_execute() && rt.has_artifact(&format!("gemm_p32_quire_{n}")))
            .map(|rt| rt.gemm_p32("quire", n, &a, &b))
            .transpose()?;
        if art.is_some() {
            pjrt_executes = true;
        }

        assert_eq!(sim_bits, native, "simulator vs native disagree at n={n}");
        let legs = if let Some(art) = &art {
            assert_eq!(art, &native, "PJRT artifact vs native disagree at n={n}");
            "sim ≡ native ≡ pjrt"
        } else {
            "sim ≡ native (pjrt leg unavailable)"
        };

        // Accuracy vs f64 golden, posit vs f32 (the §7.1 comparison).
        let golden = gemm_native(NativeKind::F64Fused, n, &af, &bf);
        let posit_vals: Vec<f64> = native.iter().map(|v| Posit32(*v).to_f64()).collect();
        let f32_vals = gemm_native(NativeKind::F32Fused, n, &af, &bf);
        let mse_p = mse(&posit_vals, &golden);
        let mse_f = mse(&f32_vals, &golden);

        println!(
            "n={n:<3} {legs} ✓   sim {} ({} cycles, IPC {:.2}, D$ miss {:.1}%)",
            fmt_time(sim.seconds),
            sim.stats.cycles,
            sim.stats.ipc(),
            100.0 * sim.stats.dcache_misses as f64
                / (sim.stats.dcache_hits + sim.stats.dcache_misses).max(1) as f64,
        );
        println!(
            "      MSE vs f64: posit32+quire {mse_p:.3e}  vs  f32 {mse_f:.3e}  (×{:.0} better)",
            mse_f / mse_p.max(f64::MIN_POSITIVE)
        );
    }

    // Coordinator-level cross-check (the L3 request path).
    println!("\n=== coordinator cross-check (4 workers) ===");
    let co = Coordinator::new(4, Some("artifacts".into()));
    let n = 8;
    let a: Vec<u32> =
        (0..n * n).map(|_| Posit32::from_f64(rng.range_f64(-1.0, 1.0)).bits()).collect();
    let b: Vec<u32> =
        (0..n * n).map(|_| Posit32::from_f64(rng.range_f64(-1.0, 1.0)).bits()).collect();
    // Include the PJRT backend only when a leg actually executed above
    // (artifact on disk AND a runtime that can run it).
    let backends: Vec<Backend> = if pjrt_executes {
        vec![Backend::Native, Backend::Sim, Backend::Pjrt]
    } else {
        vec![Backend::Native, Backend::Sim]
    };
    let results = co.cross_check(Job::GemmP32 { n, a, b, quire: true }, &backends)?;
    for r in &results {
        println!(
            "  {:?}: host {:.3} ms{}",
            r.backend,
            r.elapsed_s * 1e3,
            r.sim_seconds.map(|s| format!(", simulated {}", fmt_time(s))).unwrap_or_default()
        );
    }
    println!("metrics: {}", co.metrics.summary());
    co.shutdown();
    println!("\nEND-TO-END: all legs agree bit-for-bit ✓");
    Ok(())
}
