//! Quickstart: the posit arithmetic API in five minutes.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use percival::posit::{Posit16, Posit32, Posit8, Quire32};

fn main() {
    // ── Construction and conversion ─────────────────────────────────────
    let a = Posit32::from_f64(3.25);
    let b = Posit32::from_f64(-7.5);
    println!("a = {a:?}");
    println!("b = {b:?}");

    // ── COMP: add / sub / mul, approximate and exact div/sqrt ──────────
    println!("a + b        = {}", a + b);
    println!("a - b        = {}", a - b);
    println!("a * b        = {}", a * b);
    println!("a / b exact  = {}", a.div_exact(b));
    println!("a / b approx = {}  (PDIV.S, log-approximate, §4.1)", a.div_approx(b));
    println!("sqrt exact   = {}", Posit32::from_f64(2.0).sqrt_exact());
    println!("sqrt approx  = {}", Posit32::from_f64(2.0).sqrt_approx());

    // ── Comparisons run as integer compares (the ALU trick, §2.1) ──────
    println!("a < b  = {}   (signed-int compare on patterns)", a < b);
    println!("NaR is the least posit: {}", Posit32::NAR < Posit32::from_f64(-1e30));

    // ── FUSED: the quire — the paper's headline feature ─────────────────
    // (1e8·1e8 + 1·1 − 1e8·1e8) computed exactly:
    let big = Posit32::from_f64(1.0e8);
    let one = Posit32::ONE;
    let mut q = Quire32::new(); // QCLR.S
    q.madd(big.bits(), big.bits()); // QMADD.S
    q.madd(one.bits(), one.bits());
    q.msub(big.bits(), big.bits()); // QMSUB.S
    let fused = Posit32(q.round()); // QROUND.S
    let unfused = (big * big + one * one) - big * big;
    println!("quire   result = {fused}   (exact)");
    println!("unfused result = {unfused}   (the 1 is lost to rounding)");

    // ── Other widths ────────────────────────────────────────────────────
    println!("Posit8  1/3 ≈ {}", Posit8::from_f64(1.0 / 3.0));
    println!("Posit16 1/3 ≈ {}", Posit16::from_f64(1.0 / 3.0));
    println!("Posit32 1/3 ≈ {}", Posit32::from_f64(1.0 / 3.0));
    println!("maxpos32 = {} = 2^120", Posit32::MAXPOS);
    println!("minpos32 = {} = 2^-120", Posit32::MINPOS);
}
