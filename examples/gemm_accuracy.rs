//! Regenerate the paper's accuracy study: Table 6 and Fig. 7 (GEMM MSE vs
//! the 64-bit IEEE golden result), extended with a **Posit64** column —
//! the format-generic core instantiated at 64 bits with its 1024-bit
//! quire (Big-PERCIVAL configuration). At that width the posit tracks the
//! f64 golden at the golden's own rounding noise floor, which is the
//! 64-bit analogue of the paper's Table 9 comparison.
//!
//! ```sh
//! cargo run --release --example gemm_accuracy            # full (16…256)
//! cargo run --release --example gemm_accuracy -- --quick # 16…64
//! ```

use percival::bench::tables;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let sizes: &[usize] = if quick { &[16, 32, 64] } else { &tables::SIZES };
    tables::table6(sizes, Some("results/table6.csv"));
    tables::fig7(sizes, Some("results/fig7.csv"));
    println!("\nCSV written to results/table6.csv and results/fig7.csv");
    println!("(rows labelled \"Posit64\" are the format-generic core at 64 bits)");
}
